//! [`JitBackend`] — the plan-time compiled execution path.
//!
//! `plan()` lowers the module/block + [`crate::quant::BitProfile`]
//! through [`crate::kernel`] into one straight-line
//! [`KernelProgram`] — every fold constant, clamp range, GELU table and
//! dimension baked in at lowering time, weights repacked into narrow
//! `i8` storage for the SIMD GEMM microkernels — and picks the
//! execution strategy once: the GEMM ISA by runtime CPU detection
//! (`IVIT_KERNEL_ISA` overrides) and a persistent `jit` worker pool
//! when `--workers N` asks for shard parallelism. [`JitPlan`] then
//! executes batches with no per-request branching on profile, geometry
//! or strategy. Output codes (and the W_O fp values at attention
//! scope) are bit-identical to [`super::ReferenceBackend`] for every
//! (ISA, workers) pair — the contract `tests/kernel_parity.rs` pins at
//! DeiT-S dimensions.

use std::sync::Arc;
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::block::EncoderBlock;
use crate::kernel::{lower_attention, lower_block, Isa, KernelProgram, ProgramExecutor};

use super::{
    ensure_plan_profile, AttnBatchRequest, AttnBatchResponse, AttnModule, AttnRequest,
    AttnResponse, Backend, Capabilities, ExecutionPlan, JobId, JobState, PlanOptions, PlanScope,
    SyncJobs,
};

/// The kernel-compiler backend: lowering happens at plan time, batch
/// execution runs the compiled program.
#[derive(Debug)]
pub struct JitBackend {
    module: AttnModule,
    /// The encoder block this backend lowers at [`PlanScope::Block`];
    /// `None` for attention-only backends.
    block: Option<EncoderBlock>,
    /// Default shard parallelism for plans (0 = let [`PlanOptions`] or
    /// the machine decide, mirroring the sim-mt backend).
    workers: usize,
    /// Resident attention program + executor for the single-request
    /// adapter (so repeated `run_attention` calls lower once, like the
    /// other built-ins' resident-plan paths).
    resident: Option<(Arc<KernelProgram>, ProgramExecutor)>,
}

impl JitBackend {
    pub fn new(module: AttnModule) -> JitBackend {
        JitBackend { module, block: None, workers: 0, resident: None }
    }

    /// A backend that can plan the whole encoder block (its attention
    /// half also serves [`PlanScope::Attention`] plans).
    pub fn for_block(block: EncoderBlock) -> JitBackend {
        JitBackend { module: block.attn.clone(), block: Some(block), workers: 0, resident: None }
    }

    /// Default worker count for plans created without an explicit
    /// [`PlanOptions::workers`].
    pub fn with_workers(mut self, workers: usize) -> JitBackend {
        self.workers = workers;
        self
    }

    pub fn module(&self) -> &AttnModule {
        &self.module
    }

    pub fn block(&self) -> Option<&EncoderBlock> {
        self.block.as_ref()
    }

    fn resolve_workers(&self, opts: &PlanOptions) -> usize {
        let w = if opts.workers > 0 {
            opts.workers
        } else if self.workers > 0 {
            self.workers
        } else {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
        };
        w.max(1)
    }
}

/// A compiled program, its plan-time execution strategy (ISA + shard
/// pool) and the synchronous job parking lot: `submit` executes the
/// batch through the program inline and parks the response for `poll`.
#[derive(Debug)]
pub struct JitPlan {
    program: Arc<KernelProgram>,
    executor: ProgramExecutor,
    jobs: SyncJobs<AttnBatchResponse>,
}

impl JitPlan {
    pub fn new(program: KernelProgram, workers: usize) -> Result<JitPlan> {
        let executor = ProgramExecutor::pooled(Isa::resolve()?, workers);
        Ok(JitPlan { program: Arc::new(program), executor, jobs: SyncJobs::new() })
    }

    /// The lowered program (disassemble it with `format!("{}", …)`).
    pub fn program(&self) -> &KernelProgram {
        &self.program
    }

    /// The plan-time execution strategy.
    pub fn executor(&self) -> &ProgramExecutor {
        &self.executor
    }

    fn execute(&self, req: &AttnBatchRequest) -> Result<AttnBatchResponse> {
        let t0 = Instant::now();
        let items = req
            .items
            .iter()
            .map(|r| {
                let row_t0 = Instant::now();
                let (out, values) = self.executor.run(&self.program, &r.x)?;
                Ok(AttnResponse {
                    out_codes: Some(out),
                    out_values: values,
                    stages: None,
                    report: None,
                    elapsed: row_t0.elapsed(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(AttnBatchResponse { items, report: None, elapsed: t0.elapsed() })
    }
}

impl ExecutionPlan for JitPlan {
    fn backend_name(&self) -> &str {
        "jit"
    }

    fn describe(&self) -> String {
        format!(
            "{}, isa {}, {} workers",
            self.program.summary(),
            self.executor.isa().as_str(),
            self.executor.workers()
        )
    }

    fn submit(&mut self, req: &AttnBatchRequest) -> Result<JobId> {
        let result = self.execute(req);
        Ok(self.jobs.push(result))
    }

    fn poll(&mut self, job: JobId) -> Result<JobState<AttnBatchResponse>> {
        self.jobs.poll(job, "jit plan")
    }
}

impl Backend for JitBackend {
    fn name(&self) -> &str {
        "jit"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities { bit_exact_codes: true, hardware_stats: false, needs_artifacts: false }
    }

    fn describe(&self) -> String {
        match &self.block {
            Some(b) => format!("plan-time kernel compiler, {}", b.describe()),
            None => format!(
                "plan-time kernel compiler: D_in={} D_out={} heads={} bits[{}] ({}{})",
                self.module.d_in(),
                self.module.d_out(),
                self.module.heads,
                self.module.profile.key(),
                if self.module.shift { "shift-exp" } else { "exact-exp" },
                if self.module.wo.is_some() { ", W_O wired" } else { "" },
            ),
        }
    }

    fn plan(&self, opts: &PlanOptions) -> Result<Box<dyn ExecutionPlan>> {
        let workers = self.resolve_workers(opts);
        match opts.scope {
            PlanScope::Attention => {
                ensure_plan_profile(&opts.profile, &self.module.profile, "jit attention module")?;
                Ok(Box::new(JitPlan::new(lower_attention(&self.module)?, workers)?))
            }
            PlanScope::Block => {
                let block = self.block.as_ref().ok_or_else(|| {
                    anyhow!("jit backend was built without an encoder block (scope=Block)")
                })?;
                ensure_plan_profile(&opts.profile, &block.profile, "jit encoder block")?;
                Ok(Box::new(JitPlan::new(lower_block(block)?, workers)?))
            }
        }
    }

    /// Single-request adapter over a resident compiled program: lowers
    /// the attention module on first use, then every call executes the
    /// cached program (the default adapter would re-plan — and reject
    /// non-default profiles at its `PlanOptions::default()` boundary).
    fn run_attention(&mut self, req: &AttnRequest) -> Result<AttnResponse> {
        if self.resident.is_none() {
            let program = Arc::new(lower_attention(&self.module)?);
            let workers = self.resolve_workers(&PlanOptions::default());
            let executor = ProgramExecutor::pooled(Isa::resolve()?, workers);
            self.resident = Some((program, executor));
        }
        let (program, executor) = self.resident.as_ref().expect("lowered above");
        let t0 = Instant::now();
        let (out, values) = executor.run(program, &req.x)?;
        Ok(AttnResponse {
            out_codes: Some(out),
            out_values: values,
            stages: None,
            report: None,
            elapsed: t0.elapsed(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::{BitProfile, QTensor, QuantSpec, ReferenceBackend, Step};
    use super::*;
    use crate::quant::linear::IntMat;

    #[test]
    fn jit_attention_matches_ref_on_a_tiny_module() {
        let module = AttnModule::synthetic(16, 8, 2, BitProfile::uniform(3), 5).unwrap();
        let x = module.random_input(6, 3).unwrap();
        let mut jit = JitBackend::new(module.clone());
        let mut reference = ReferenceBackend::new(module);
        let a = jit.run_attention(&AttnRequest::new(x.clone())).unwrap();
        let b = reference.run_attention(&AttnRequest::new(x)).unwrap();
        assert_eq!(
            a.out_codes.as_ref().unwrap().codes.data,
            b.out_codes.as_ref().unwrap().codes.data
        );
        assert_eq!(a.out_values, b.out_values);
        assert!(jit.capabilities().bit_exact_codes);
    }

    #[test]
    fn jit_block_plan_matches_block_reference() {
        let block = EncoderBlock::synthetic(12, 24, 2, BitProfile::uniform(3), 31).unwrap();
        let x = block.random_input(4, 1).unwrap();
        let want = block.run_reference(&x).unwrap();
        let backend = JitBackend::for_block(block);
        let opts = PlanOptions { scope: PlanScope::Block, ..PlanOptions::default() };
        let mut plan = backend.plan(&opts).unwrap();
        assert!(plan.describe().contains("compiled kernel program"));
        assert!(plan.describe().contains("workers"));
        let resp = plan.run_one(&AttnRequest::new(x)).unwrap();
        assert_eq!(resp.out_codes.unwrap().codes.data, want.codes.data);
        // attention-only jit backends refuse block scope
        let plain =
            JitBackend::new(AttnModule::synthetic(12, 6, 2, BitProfile::uniform(3), 1).unwrap());
        assert!(plain.plan(&opts).is_err());
    }

    #[test]
    fn jit_plan_output_is_identical_for_any_worker_count() {
        let block = EncoderBlock::synthetic(12, 24, 2, BitProfile::uniform(3), 31).unwrap();
        let x = block.random_input(9, 2).unwrap();
        let opts = |workers| PlanOptions {
            scope: PlanScope::Block,
            workers,
            ..PlanOptions::default()
        };
        let backend = JitBackend::for_block(block);
        let mut single = backend.plan(&opts(1)).unwrap();
        let base = single.run_one(&AttnRequest::new(x.clone())).unwrap();
        for workers in [2usize, 3, 5] {
            let mut plan = backend.plan(&opts(workers)).unwrap();
            let got = plan.run_one(&AttnRequest::new(x.clone())).unwrap();
            assert_eq!(
                got.out_codes.as_ref().unwrap().codes.data,
                base.out_codes.as_ref().unwrap().codes.data,
                "jit output changed at {workers} workers"
            );
        }
    }

    #[test]
    fn jit_rejects_profile_and_step_mismatches() {
        let module = AttnModule::synthetic(12, 6, 2, BitProfile::uniform(4), 7).unwrap();
        let backend = JitBackend::new(module.clone());
        // plan-time: profile mismatch is loud
        assert!(backend.plan(&PlanOptions::default()).is_err());
        let opts = PlanOptions::for_profile(BitProfile::uniform(4));
        let mut plan = backend.plan(&opts).unwrap();
        // run-time: a near-miss input step is rejected (compiled kernels
        // require the exact step they were lowered against)
        let near = QuantSpec::signed(4, Step::new(0.120001).unwrap());
        let bad = QTensor::new(IntMat::new(2, 12, vec![0; 24]), near).unwrap();
        assert!(plan.run_one(&AttnRequest::new(bad)).is_err());
        // the exact step passes
        let good = module.random_input(2, 9).unwrap();
        assert!(plan.run_one(&AttnRequest::new(good)).is_ok());
    }
}
