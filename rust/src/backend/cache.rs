//! [`PlanCache`] — plan-level memoization (the ROADMAP follow-up to the
//! plan/execute redesign).
//!
//! `Backend::plan` performs all one-time work (scale folding, `to_sim`
//! lowering, engine binding, worker-pool spawn). Repeated
//! `serve`/`simulate` invocations in one process used to rebuild that
//! plan every time; the cache keys plans by **backend name +
//! description + [`PlanOptions`]** and hands back the resident plan on
//! a hit, so cold and warm calls execute the *same* plan object (and
//! are therefore trivially bit-identical — pinned by tests).
//!
//! ### Key semantics (and their limit)
//!
//! The key is textual: `name | describe() | <full serialized
//! PlanOptions>` — the options half is [`PlanOptions::key`], the
//! canonical JSON rendering of *every* options field (workers,
//! row-shard threshold, scope, and the complete per-site bit profile),
//! never a hand-picked subset, so two configurations differing only in
//! precision can never alias. Backend `describe()` strings carry the
//! module geometry, bit profile and (for block backends) the block
//! label, so distinct configurations and distinct stacked blocks get
//! distinct entries. Two backends with the *same* description but
//! different weights would collide — callers juggling same-shaped,
//! differently-weighted modules in one process must label them (see
//! [`crate::block::EncoderBlock::label`]) or use separate caches.
//!
//! ### Bounded residency (LRU)
//!
//! Plans hold live state — worker pools, bound engines, compiled
//! kernel programs with repacked weights — so unbounded residency is a
//! memory leak in long-lived serving processes. The cache is bounded:
//! at most [`DEFAULT_PLAN_CAPACITY`] plans stay resident (configurable
//! via [`PlanCache::with_capacity`] / [`PlanCache::set_capacity`]), and
//! inserting past the bound evicts the least-recently-used entry
//! ([`PlanCache::evictions`] counts them). Eviction drops only the
//! resident plan — the [`PlanSeed`] rebuild index survives, so evicted
//! seeded entries still persist and re-plan bit-identically on the
//! next lookup (pinned by tests).
//!
//! A process-wide instance is available through [`PlanCache::global`]
//! (what `ivit simulate` routes through).
//!
//! ### Persistence across coordinator restarts
//!
//! Plans themselves hold live state (worker pools, bound engines) and
//! cannot be serialized — but everything needed to **rebuild** them
//! can. A [`PlanSeed`] is the JSON-serializable rebuild recipe (registry
//! name + [`PlanOptions`] + the synthetic/attn_case geometry the
//! [`BackendRegistry`] consumes); callers that plan through
//! [`PlanCache::get_or_plan_seeded`] / [`PlanCache::take_or_plan_seeded`]
//! record the seed alongside the resident plan, [`PlanCache::persist`]
//! writes the `(key, seed)` index to a `plan_cache.json` sidecar under a
//! cache dir, and [`PlanCache::warm_start`] rebuilds every entry on the
//! next startup — so a restarted `ivit serve --cache-dir DIR` begins
//! with its plans resident and cold ≡ warm outputs stay bit-identical
//! (synthetic modules are deterministic functions of their geometry +
//! seed; pinned by tests).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, OnceLock};

use anyhow::{anyhow, ensure, Context, Result};

use crate::block::EncoderBlock;
use crate::util::Json;

use super::registry::{BackendConfig, BackendRegistry};
use super::{Backend, ExecutionPlan, PlanOptions, PlanScope};

/// Resident plans a cache holds before evicting: generous enough for a
/// full DeiT-S block stack per backend with headroom, small enough to
/// bound a long-lived server.
pub const DEFAULT_PLAN_CAPACITY: usize = 64;

/// Name-keyed LRU memoization of [`ExecutionPlan`]s, with an optional
/// [`PlanSeed`] index for the entries that can be rebuilt across
/// process restarts. At most `capacity` plans stay resident; the seed
/// index is unbounded (seeds are tiny, and dropping one would silently
/// shrink the persisted sidecar).
pub struct PlanCache {
    plans: BTreeMap<String, Box<dyn ExecutionPlan>>,
    seeds: BTreeMap<String, PlanSeed>,
    /// Last-use stamp per *resident* plan; the minimum is the LRU.
    stamps: BTreeMap<String, u64>,
    clock: u64,
    capacity: usize,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl Default for PlanCache {
    fn default() -> Self {
        PlanCache::with_capacity(DEFAULT_PLAN_CAPACITY)
    }
}

/// The JSON-serializable recipe for rebuilding one cached plan after a
/// coordinator restart: the registry name, the **full** [`PlanOptions`]
/// (bit profile included), and the scalar config the
/// [`BackendRegistry`] factory consumes. Synthetic modules/blocks are
/// deterministic functions of `(geometry, profile, seed)` and attn_case
/// replays are deterministic functions of the artifacts dir, so a
/// rebuilt plan is bit-identical to the one that was persisted — and
/// because the profile rides inside the options, two persisted entries
/// differing only in precision can never alias.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanSeed {
    /// Registry name, e.g. `"sim-mt"`.
    pub backend: String,
    /// The complete plan options — workers, row-shard threshold, scope
    /// and the per-site [`crate::quant::BitProfile`].
    pub options: PlanOptions,
    /// Module / block model dimension (blocks are D→D square).
    pub d_in: usize,
    /// Attention head dim (attention scope).
    pub d_head: usize,
    pub heads: usize,
    /// MLP hidden width (block scope only; ignored at attention scope).
    pub hidden: usize,
    /// Eq. 4 shift exponential (attention scope; synthetic blocks always
    /// use it).
    pub shift: bool,
    /// Synthetic parameter seed.
    pub seed: u64,
    /// Artifacts dir whose exported attn_case overrides the synthetic
    /// module (attention scope only).
    pub artifacts: Option<String>,
}

impl PlanSeed {
    /// The plan options this seed rebuilds with.
    pub fn options(&self) -> PlanOptions {
        self.options.clone()
    }

    /// The backend config this seed rebuilds with. Block-scope seeds
    /// regenerate their synthetic [`EncoderBlock`]; attention-scope
    /// seeds resolve through the usual module path (attn_case when the
    /// artifacts dir holds one, else the synthetic geometry).
    pub fn to_config(&self) -> Result<BackendConfig> {
        let block = match self.options.scope {
            PlanScope::Attention => None,
            PlanScope::Block => Some(EncoderBlock::synthetic(
                self.d_in,
                self.hidden,
                self.heads,
                self.options.profile,
                self.seed,
            )?),
        };
        Ok(BackendConfig {
            module: None,
            block,
            artifacts: self.artifacts.as_ref().map(PathBuf::from),
            d_in: self.d_in,
            d_head: self.d_head,
            heads: self.heads,
            profile: self.options.profile,
            shift: self.shift,
            seed: self.seed,
            workers: self.options.workers,
        })
    }

    fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("backend".into(), Json::Str(self.backend.clone()));
        // the FULL serialized options — not hand-picked fields
        obj.insert("options".into(), self.options.to_json());
        obj.insert("d_in".into(), Json::Num(self.d_in as f64));
        obj.insert("d_head".into(), Json::Num(self.d_head as f64));
        obj.insert("heads".into(), Json::Num(self.heads as f64));
        obj.insert("hidden".into(), Json::Num(self.hidden as f64));
        obj.insert("shift".into(), Json::Bool(self.shift));
        // u64 seeds don't survive the f64 JSON number path above 2^53,
        // and a rounded seed would silently regenerate different
        // synthetic weights — keep the full precision in a string
        obj.insert("seed".into(), Json::Str(self.seed.to_string()));
        obj.insert(
            "artifacts".into(),
            match &self.artifacts {
                Some(p) => Json::Str(p.clone()),
                None => Json::Null,
            },
        );
        Json::Obj(obj)
    }

    fn from_json(j: &Json) -> Result<PlanSeed> {
        let str_field = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("plan seed: missing string field '{k}'"))
        };
        let num = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("plan seed: missing numeric field '{k}'"))
        };
        Ok(PlanSeed {
            backend: str_field("backend")?,
            options: PlanOptions::from_json(
                j.get("options").ok_or_else(|| anyhow!("plan seed: missing 'options'"))?,
            )?,
            d_in: num("d_in")? as usize,
            d_head: num("d_head")? as usize,
            heads: num("heads")? as usize,
            hidden: num("hidden")? as usize,
            shift: matches!(j.get("shift"), Some(Json::Bool(true))),
            seed: str_field("seed")?
                .parse::<u64>()
                .map_err(|_| anyhow!("plan seed: 'seed' is not a u64"))?,
            artifacts: j.get("artifacts").and_then(Json::as_str).map(str::to_string),
        })
    }
}

/// File name of the persisted index inside a cache dir.
pub const PLAN_CACHE_FILE: &str = "plan_cache.json";

/// Sidecar schema version. v2 embeds the full [`PlanOptions`] — bit
/// profile included — per entry; v1 sidecars (pre-profile) are rejected
/// loudly rather than silently rebuilt at a guessed precision.
pub const PLAN_CACHE_VERSION: f64 = 2.0;

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// A cache that keeps at most `capacity` plans resident (clamped to
    /// at least 1 — a zero-capacity cache could never return a borrow).
    pub fn with_capacity(capacity: usize) -> PlanCache {
        PlanCache {
            plans: BTreeMap::new(),
            seeds: BTreeMap::new(),
            stamps: BTreeMap::new(),
            clock: 0,
            capacity: capacity.max(1),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Change the residency bound, evicting LRU entries immediately if
    /// the cache is over the new bound.
    pub fn set_capacity(&mut self, capacity: usize) {
        self.capacity = capacity.max(1);
        while self.plans.len() > self.capacity {
            self.evict_lru();
        }
    }

    /// The residency bound.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    fn touch(&mut self, key: &str) {
        self.clock += 1;
        self.stamps.insert(key.to_string(), self.clock);
    }

    /// Drop the least-recently-used resident plan. The seed index is
    /// untouched: evicted seeded entries still persist and rebuild.
    fn evict_lru(&mut self) {
        let lru = self
            .stamps
            .iter()
            .min_by_key(|(_, &stamp)| stamp)
            .map(|(key, _)| key.clone());
        if let Some(key) = lru {
            self.plans.remove(&key);
            self.stamps.remove(&key);
            self.evictions += 1;
        }
    }

    /// Make room if needed, insert, and stamp the entry most-recent.
    fn insert_resident(&mut self, key: String, plan: Box<dyn ExecutionPlan>) {
        while self.plans.len() >= self.capacity {
            self.evict_lru();
        }
        self.touch(&key);
        self.plans.insert(key, plan);
    }

    /// The cache key for planning `backend` with `opts`: backend name,
    /// backend description, and the **full serialized** [`PlanOptions`]
    /// ([`PlanOptions::key`]) — every options field, bit profile
    /// included, keys plans apart. Hand-picked fields are exactly the
    /// bug this replaces: an option added later (like the profile)
    /// could silently alias two different plans.
    pub fn key(backend: &dyn Backend, opts: &PlanOptions) -> String {
        format!("{}|{}|{}", backend.name(), backend.describe(), opts.key())
    }

    /// Return the resident plan for `(backend, opts)`, planning it on
    /// first use. The returned borrow is the cached instance itself, so
    /// warm callers reuse folded scales, lowered simulators and worker
    /// pools without paying plan-time work again.
    pub fn get_or_plan(
        &mut self,
        backend: &dyn Backend,
        opts: &PlanOptions,
    ) -> Result<&mut dyn ExecutionPlan> {
        let key = Self::key(backend, opts);
        if self.plans.contains_key(&key) {
            self.hits += 1;
            self.touch(&key);
        } else {
            self.misses += 1;
            let plan = backend.plan(opts)?;
            self.insert_resident(key.clone(), plan);
        }
        Ok(self.plans.get_mut(&key).expect("resident above").as_mut())
    }

    /// Like [`Self::get_or_plan`], but through a rebuildable
    /// [`PlanSeed`]: the backend is constructed from the seed's config,
    /// the seed is recorded in the persistence index, and the resident
    /// plan is returned (built on first use). Computing the textual key
    /// requires building the backend even on a hit — plan-time work is
    /// still saved, construction-time work is not.
    pub fn get_or_plan_seeded(
        &mut self,
        registry: &BackendRegistry,
        seed: &PlanSeed,
    ) -> Result<&mut dyn ExecutionPlan> {
        let (key, backend) = self.seed_backend(registry, seed)?;
        self.seeds.insert(key.clone(), seed.clone());
        if self.plans.contains_key(&key) {
            self.hits += 1;
            self.touch(&key);
        } else {
            self.misses += 1;
            let plan = backend.plan(&seed.options())?;
            self.insert_resident(key.clone(), plan);
        }
        Ok(self.plans.get_mut(&key).expect("resident above").as_mut())
    }

    /// Like [`Self::get_or_plan_seeded`], but hands the plan out by
    /// value (removed from the cache) — what `ivit serve` needs, since
    /// the executor moves the plan onto the coordinator worker thread.
    /// The seed stays recorded, so [`Self::persist`] still writes the
    /// entry and the *next* process warm-loads it.
    pub fn take_or_plan_seeded(
        &mut self,
        registry: &BackendRegistry,
        seed: &PlanSeed,
    ) -> Result<Box<dyn ExecutionPlan>> {
        let (key, backend) = self.seed_backend(registry, seed)?;
        self.seeds.insert(key.clone(), seed.clone());
        match self.plans.remove(&key) {
            Some(plan) => {
                self.stamps.remove(&key);
                self.hits += 1;
                Ok(plan)
            }
            None => {
                self.misses += 1;
                backend.plan(&seed.options())
            }
        }
    }

    fn seed_backend(
        &self,
        registry: &BackendRegistry,
        seed: &PlanSeed,
    ) -> Result<(String, Box<dyn Backend>)> {
        let cfg = seed.to_config()?;
        let backend = registry.create(&seed.backend, &cfg)?;
        let key = Self::key(&*backend, &seed.options());
        Ok((key, backend))
    }

    /// Write the `(key, seed)` index of every seeded entry to
    /// `<dir>/plan_cache.json`, creating the dir if needed. Returns the
    /// sidecar path. Unseeded entries (plans built through the plain
    /// [`Self::get_or_plan`]) have no rebuild recipe and are skipped.
    pub fn persist(&self, dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating cache dir {dir:?}"))?;
        let entries: Vec<Json> = self
            .seeds
            .iter()
            .map(|(key, seed)| {
                let mut obj = BTreeMap::new();
                obj.insert("key".to_string(), Json::Str(key.clone()));
                obj.insert("seed".to_string(), seed.to_json());
                Json::Obj(obj)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("version".to_string(), Json::Num(PLAN_CACHE_VERSION));
        root.insert("entries".to_string(), Json::Arr(entries));
        let path = dir.join(PLAN_CACHE_FILE);
        std::fs::write(&path, format!("{}\n", Json::Obj(root)))
            .with_context(|| format!("writing {path:?}"))?;
        Ok(path)
    }

    /// Rebuild a cache from `<dir>/plan_cache.json`: every persisted
    /// seed is re-planned (backend construction + `Backend::plan`), so
    /// the returned cache starts with all plans resident — the next
    /// seeded lookup is a hit. A missing sidecar yields an empty cache;
    /// a corrupted one (unreadable, unparseable, or a stored key that
    /// no longer matches its rebuilt backend) is a loud error, never a
    /// silent partial load.
    pub fn warm_start(dir: &Path, registry: &BackendRegistry) -> Result<PlanCache> {
        Self::warm_start_filtered(dir, registry, |_| true)
    }

    /// Like [`Self::warm_start`], but only re-plans the entries `want`
    /// accepts (skipped entries pay no backend construction or
    /// plan-time cost). The **full** seed index is always loaded, so a
    /// later [`Self::persist`] keeps every persisted entry; skipped
    /// entries keep their stored key unvalidated.
    pub fn warm_start_filtered(
        dir: &Path,
        registry: &BackendRegistry,
        want: impl Fn(&PlanSeed) -> bool,
    ) -> Result<PlanCache> {
        let path = dir.join(PLAN_CACHE_FILE);
        let mut cache = PlanCache::new();
        let text = match std::fs::read_to_string(&path) {
            Ok(text) => text,
            // only a MISSING sidecar is a cold start; an unreadable one
            // must fail loud, not silently discard the persisted index
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(cache),
            Err(e) => return Err(e).with_context(|| format!("reading {path:?}")),
        };
        let root = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        let version = root.get("version").and_then(Json::as_f64).unwrap_or(0.0);
        ensure!(
            version == PLAN_CACHE_VERSION,
            "{path:?}: unsupported plan-cache version {version} (this build writes \
             {PLAN_CACHE_VERSION}; delete the sidecar to start cold)"
        );
        let entries = root
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("{path:?}: missing 'entries' array"))?;
        for (i, entry) in entries.iter().enumerate() {
            let stored_key = entry
                .get("key")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("{path:?}: entry {i} has no key"))?;
            let seed = PlanSeed::from_json(
                entry.get("seed").ok_or_else(|| anyhow!("{path:?}: entry {i} has no seed"))?,
            )
            .with_context(|| format!("{path:?}: entry {i}"))?;
            if !want(&seed) {
                cache.seeds.insert(stored_key.to_string(), seed);
                continue;
            }
            let (key, backend) = cache.seed_backend(registry, &seed)?;
            ensure!(
                key == stored_key,
                "{path:?}: entry {i} key mismatch — persisted for a different build?\n  \
                 stored : {stored_key}\n  rebuilt: {key}"
            );
            let plan = backend
                .plan(&seed.options())
                .with_context(|| format!("{path:?}: rebuilding plan for entry {i}"))?;
            cache.insert_resident(key.clone(), plan);
            cache.seeds.insert(key, seed);
        }
        Ok(cache)
    }

    /// Plans served from the cache.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Plans built (first use of a key).
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Resident plans dropped to stay under the capacity bound.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Resident plan count.
    pub fn len(&self) -> usize {
        self.plans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.plans.is_empty()
    }

    /// Drop every resident plan (worker pools join on drop) and the
    /// seed index.
    pub fn clear(&mut self) {
        self.plans.clear();
        self.stamps.clear();
        self.seeds.clear();
    }

    /// The process-wide cache (plans survive across command invocations
    /// inside one process).
    pub fn global() -> &'static Mutex<PlanCache> {
        static GLOBAL: OnceLock<Mutex<PlanCache>> = OnceLock::new();
        GLOBAL.get_or_init(|| Mutex::new(PlanCache::new()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::BitProfile;
    use crate::backend::{
        AttnBatchRequest, AttnModule, AttnRequest, PlanScope, ReferenceBackend, SimBackend,
    };
    use crate::block::EncoderBlock;

    #[test]
    fn cache_hit_returns_the_resident_plan_and_outputs_stay_bit_identical() {
        let module = AttnModule::synthetic(12, 6, 2, BitProfile::uniform(3), 5).unwrap();
        let backend = ReferenceBackend::new(module.clone());
        let mut cache = PlanCache::new();
        let opts = PlanOptions::default();
        let req = AttnBatchRequest::single(AttnRequest::new(module.random_input(4, 1).unwrap()));

        let cold = cache.get_or_plan(&backend, &opts).unwrap().run_batch(&req).unwrap();
        assert_eq!((cache.misses(), cache.hits(), cache.len()), (1, 0, 1));
        let warm = cache.get_or_plan(&backend, &opts).unwrap().run_batch(&req).unwrap();
        // the second lookup did NOT build a plan — it reused the resident one
        assert_eq!((cache.misses(), cache.hits(), cache.len()), (1, 1, 1));
        assert_eq!(
            cold.items[0].out_codes.as_ref().unwrap().codes.data,
            warm.items[0].out_codes.as_ref().unwrap().codes.data,
            "cold and warm outputs must be bit-identical"
        );
    }

    #[test]
    fn distinct_options_and_backends_get_distinct_entries() {
        let module = AttnModule::synthetic(12, 6, 2, BitProfile::uniform(3), 5).unwrap();
        let r = ReferenceBackend::new(module.clone());
        let s = SimBackend::new(module);
        let mut cache = PlanCache::new();
        cache.get_or_plan(&r, &PlanOptions::default()).unwrap();
        cache.get_or_plan(&s, &PlanOptions::default()).unwrap();
        cache
            .get_or_plan(&r, &PlanOptions { workers: 3, ..PlanOptions::default() })
            .unwrap();
        assert_eq!((cache.misses(), cache.hits(), cache.len()), (3, 0, 3));
        cache.clear();
        assert!(cache.is_empty());
    }

    fn temp_cache_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "ivit_plan_cache_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn block_seed() -> PlanSeed {
        PlanSeed {
            backend: "sim".into(),
            options: PlanOptions {
                scope: PlanScope::Block,
                ..PlanOptions::default()
            },
            d_in: 12,
            d_head: 6,
            heads: 2,
            hidden: 24,
            shift: true,
            seed: 19,
            artifacts: None,
        }
    }

    #[test]
    fn seed_json_roundtrips() {
        let seed = block_seed();
        let j = seed.to_json();
        let text = format!("{j}");
        let back = PlanSeed::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, seed);
        // attention-scope seed with artifacts path and a mixed profile
        // survives too
        let attn = PlanSeed {
            options: PlanOptions {
                profile: BitProfile::parse("attn:4,mlp:8").unwrap(),
                ..PlanOptions::default()
            },
            artifacts: Some("some/dir".into()),
            shift: false,
            ..seed
        };
        let back = PlanSeed::from_json(&Json::parse(&format!("{}", attn.to_json())).unwrap())
            .unwrap();
        assert_eq!(back, attn);
    }

    #[test]
    fn profile_only_differences_never_collide() {
        // the keying regression the refactor pins: options that differ
        // ONLY in bit profile must produce different cache entries, on
        // both the textual key and the live cache
        let u4 = BitProfile::uniform(4);
        let mixed = BitProfile::parse("attn:4,mlp:8").unwrap();
        let ba = ReferenceBackend::for_block(
            EncoderBlock::synthetic(12, 24, 2, u4, 7).unwrap(),
        );
        let bb = ReferenceBackend::for_block(
            EncoderBlock::synthetic(12, 24, 2, mixed, 7).unwrap(),
        );
        let oa = PlanOptions { scope: PlanScope::Block, profile: u4, ..PlanOptions::default() };
        let ob = PlanOptions { scope: PlanScope::Block, profile: mixed, ..PlanOptions::default() };
        assert_ne!(PlanCache::key(&ba, &oa), PlanCache::key(&bb, &ob));
        // even with an identical describe() the serialized options keep
        // the entries apart — same backend, two profiles in the options
        assert_ne!(PlanCache::key(&ba, &oa), PlanCache::key(&ba, &ob));
        let mut cache = PlanCache::new();
        cache.get_or_plan(&ba, &oa).unwrap();
        assert!(cache.get_or_plan(&ba, &ob).is_err(), "profile mismatch is loud, not a hit");
        assert_eq!(cache.len(), 1);
        cache.get_or_plan(&bb, &ob).unwrap();
        assert_eq!((cache.misses(), cache.hits(), cache.len()), (3, 0, 2));
    }

    #[test]
    fn corrupt_profile_entries_are_rejected_loudly() {
        let registry = BackendRegistry::with_defaults();
        let dir = temp_cache_dir("corrupt_profile");
        let mut cache = PlanCache::new();
        cache.get_or_plan_seeded(&registry, &block_seed()).unwrap();
        let sidecar = cache.persist(&dir).unwrap();
        // sabotage one profile site inside the persisted options
        let text = std::fs::read_to_string(&sidecar).unwrap();
        assert!(text.contains("\"gelu_in\""), "sidecar carries the full profile: {text}");
        std::fs::write(&sidecar, text.replace("\"gelu_in\":3", "\"gelu_in\":99")).unwrap();
        let err = PlanCache::warm_start(&dir, &registry).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("gelu_in") || msg.contains("bit width"), "{msg}");
        // ... and a dropped profile site is equally loud
        let text = std::fs::read_to_string(&sidecar).unwrap();
        std::fs::write(&sidecar, text.replace("\"gelu_in\":99,", "")).unwrap();
        assert!(PlanCache::warm_start(&dir, &registry).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn persisted_cache_warm_starts_with_bit_identical_outputs() {
        let registry = BackendRegistry::with_defaults();
        let seed = block_seed();
        let dir = temp_cache_dir("warm");

        // cold process: plan through the seeded path, run a batch, persist
        let block = EncoderBlock::synthetic(12, 24, 2, BitProfile::uniform(3), 19).unwrap();
        let req = AttnBatchRequest::single(AttnRequest::new(block.random_input(4, 3).unwrap()));
        let mut cold_cache = PlanCache::new();
        let cold = cold_cache
            .get_or_plan_seeded(&registry, &seed)
            .unwrap()
            .run_batch(&req)
            .unwrap();
        assert_eq!((cold_cache.misses(), cold_cache.hits()), (1, 0));
        let sidecar = cold_cache.persist(&dir).unwrap();
        assert!(sidecar.exists());

        // restarted process: warm-load → the plan is already resident,
        // the seeded lookup is a HIT, outputs are bit-identical
        let mut warm_cache = PlanCache::warm_start(&dir, &registry).unwrap();
        assert_eq!(warm_cache.len(), 1, "warm start rebuilds the persisted plan");
        let warm = warm_cache
            .get_or_plan_seeded(&registry, &seed)
            .unwrap()
            .run_batch(&req)
            .unwrap();
        assert_eq!((warm_cache.misses(), warm_cache.hits()), (0, 1), "warm lookup must hit");
        assert_eq!(
            cold.items[0].out_codes.as_ref().unwrap().codes.data,
            warm.items[0].out_codes.as_ref().unwrap().codes.data,
            "cold and warm outputs must be bit-identical across the restart"
        );

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn take_keeps_the_seed_for_persistence_and_corruption_is_loud() {
        let registry = BackendRegistry::with_defaults();
        let seed = block_seed();
        let dir = temp_cache_dir("take");

        let mut cache = PlanCache::new();
        let plan = cache.take_or_plan_seeded(&registry, &seed).unwrap();
        assert!(!plan.describe().is_empty());
        assert_eq!(cache.len(), 0, "taken plan leaves the cache");
        cache.persist(&dir).unwrap();
        // the seed was still persisted — the next process warm-loads it
        let warm = PlanCache::warm_start(&dir, &registry).unwrap();
        assert_eq!(warm.len(), 1);

        // a corrupted sidecar is an error, not a silent cold start
        std::fs::write(dir.join(PLAN_CACHE_FILE), "{not json").unwrap();
        assert!(PlanCache::warm_start(&dir, &registry).is_err());
        // ... and so is a stored key that no longer matches its seed
        let mut cache = PlanCache::new();
        cache.seeds.insert("stale|key".into(), seed);
        cache.persist(&dir).unwrap();
        let err = PlanCache::warm_start(&dir, &registry).unwrap_err();
        assert!(format!("{err:#}").contains("key mismatch"), "{err:#}");

        // missing sidecar → empty cache (cold start)
        let _ = std::fs::remove_dir_all(&dir);
        assert!(PlanCache::warm_start(&dir, &registry).unwrap().is_empty());
    }

    #[test]
    fn filtered_warm_start_skips_planning_but_keeps_the_whole_index() {
        let registry = BackendRegistry::with_defaults();
        let dir = temp_cache_dir("filter");
        let a = block_seed();
        let b = PlanSeed { seed: 21, ..block_seed() };
        let mut cache = PlanCache::new();
        cache.get_or_plan_seeded(&registry, &a).unwrap();
        cache.get_or_plan_seeded(&registry, &b).unwrap();
        cache.persist(&dir).unwrap();

        // only `a` is re-planned; `b` loads index-only
        let warm = PlanCache::warm_start_filtered(&dir, &registry, |s| s == &a).unwrap();
        assert_eq!(warm.len(), 1, "one plan resident");
        assert_eq!(warm.seeds.len(), 2, "both seeds in the index");
        // a re-persist of the filtered cache keeps BOTH entries
        warm.persist(&dir).unwrap();
        let full = PlanCache::warm_start(&dir, &registry).unwrap();
        assert_eq!(full.len(), 2, "nothing was dropped from the sidecar");

        // a u64 seed above 2^53 survives the JSON round trip exactly
        let big = PlanSeed { seed: (1u64 << 53) + 1, ..block_seed() };
        let back =
            PlanSeed::from_json(&Json::parse(&format!("{}", big.to_json())).unwrap()).unwrap();
        assert_eq!(back.seed, (1u64 << 53) + 1);

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lru_eviction_prefers_the_least_recently_used_entry() {
        let module = AttnModule::synthetic(12, 6, 2, BitProfile::uniform(3), 5).unwrap();
        let backend = ReferenceBackend::new(module);
        // three distinct keys over one backend: workers is an options field
        let oa = PlanOptions::default();
        let ob = PlanOptions { workers: 3, ..PlanOptions::default() };
        let oc = PlanOptions { workers: 5, ..PlanOptions::default() };
        let mut cache = PlanCache::with_capacity(2);
        assert_eq!(cache.capacity(), 2);
        cache.get_or_plan(&backend, &oa).unwrap(); // miss, resident {a}
        cache.get_or_plan(&backend, &ob).unwrap(); // miss, resident {a, b}
        cache.get_or_plan(&backend, &oa).unwrap(); // hit — `a` is now the MRU
        cache.get_or_plan(&backend, &oc).unwrap(); // miss — evicts `b`, the LRU
        assert_eq!(cache.evictions(), 1);
        assert_eq!(cache.len(), 2);
        cache.get_or_plan(&backend, &oa).unwrap(); // `a` survived the eviction
        assert_eq!((cache.misses(), cache.hits()), (3, 2));
        cache.get_or_plan(&backend, &ob).unwrap(); // `b` was evicted → re-planned
        assert_eq!((cache.misses(), cache.hits(), cache.evictions()), (4, 2, 2));
        // shrinking the bound evicts down immediately
        cache.set_capacity(1);
        assert_eq!((cache.len(), cache.evictions()), (1, 3));
        // a zero capacity is clamped — the cache can always hold one plan
        assert_eq!(PlanCache::with_capacity(0).capacity(), 1);
    }

    #[test]
    fn evicted_entries_replan_bit_identical() {
        let module = AttnModule::synthetic(12, 6, 2, BitProfile::uniform(3), 5).unwrap();
        let backend = ReferenceBackend::new(module.clone());
        let req = AttnBatchRequest::single(AttnRequest::new(module.random_input(4, 1).unwrap()));
        let mut cache = PlanCache::with_capacity(1);
        let oa = PlanOptions::default();
        let ob = PlanOptions { workers: 3, ..PlanOptions::default() };
        let first = cache.get_or_plan(&backend, &oa).unwrap().run_batch(&req).unwrap();
        cache.get_or_plan(&backend, &ob).unwrap(); // capacity 1 → evicts `a`
        assert_eq!(cache.evictions(), 1);
        let again = cache.get_or_plan(&backend, &oa).unwrap().run_batch(&req).unwrap();
        assert_eq!((cache.misses(), cache.hits()), (3, 0), "an evicted key re-plans, not hits");
        assert_eq!(
            first.items[0].out_codes.as_ref().unwrap().codes.data,
            again.items[0].out_codes.as_ref().unwrap().codes.data,
            "a re-planned entry must be bit-identical to the evicted one"
        );
    }

    #[test]
    fn stacked_blocks_key_apart_by_label() {
        let mut a = EncoderBlock::synthetic(12, 24, 2, BitProfile::uniform(3), 7).unwrap();
        let mut b = EncoderBlock::synthetic(12, 24, 2, BitProfile::uniform(3), 8).unwrap();
        a.label = "block0".into();
        b.label = "block1".into();
        let opts = PlanOptions { scope: PlanScope::Block, ..PlanOptions::default() };
        let ka = PlanCache::key(&ReferenceBackend::for_block(a), &opts);
        let kb = PlanCache::key(&ReferenceBackend::for_block(b), &opts);
        assert_ne!(ka, kb, "same-geometry blocks must not collide: {ka}");
        // and scope is part of the key too
        let a2 = EncoderBlock::synthetic(12, 24, 2, BitProfile::uniform(3), 7).unwrap();
        let k_attn =
            PlanCache::key(&ReferenceBackend::for_block(a2), &PlanOptions::default());
        assert_ne!(ka, k_attn);
    }
}
