//! [`PlanCache`] — plan-level memoization (the ROADMAP follow-up to the
//! plan/execute redesign).
//!
//! `Backend::plan` performs all one-time work (scale folding, `to_sim`
//! lowering, engine binding, worker-pool spawn). Repeated
//! `serve`/`simulate` invocations in one process used to rebuild that
//! plan every time; the cache keys plans by **backend name +
//! description + [`PlanOptions`]** and hands back the resident plan on
//! a hit, so cold and warm calls execute the *same* plan object (and
//! are therefore trivially bit-identical — pinned by tests).
//!
//! ### Key semantics (and their limit)
//!
//! The key is textual: `name | describe() | workers | row-shard |
//! scope`. Backend `describe()` strings carry the module geometry, bit
//! width and (for block backends) the block label, so distinct
//! configurations and distinct stacked blocks get distinct entries.
//! Two backends with the *same* description but different weights would
//! collide — callers juggling same-shaped, differently-weighted modules
//! in one process must label them (see
//! [`crate::block::EncoderBlock::label`]) or use separate caches.
//!
//! A process-wide instance is available through [`PlanCache::global`]
//! (what `ivit simulate` routes through).

use std::collections::btree_map::Entry;
use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

use anyhow::Result;

use super::{Backend, ExecutionPlan, PlanOptions};

/// Name-keyed memoization of [`ExecutionPlan`]s.
#[derive(Default)]
pub struct PlanCache {
    plans: BTreeMap<String, Box<dyn ExecutionPlan>>,
    hits: u64,
    misses: u64,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// The cache key for planning `backend` with `opts`.
    pub fn key(backend: &dyn Backend, opts: &PlanOptions) -> String {
        format!(
            "{}|{}|workers={}|rowshard={}|scope={:?}",
            backend.name(),
            backend.describe(),
            opts.workers,
            opts.row_shard_threshold,
            opts.scope,
        )
    }

    /// Return the resident plan for `(backend, opts)`, planning it on
    /// first use. The returned borrow is the cached instance itself, so
    /// warm callers reuse folded scales, lowered simulators and worker
    /// pools without paying plan-time work again.
    pub fn get_or_plan(
        &mut self,
        backend: &dyn Backend,
        opts: &PlanOptions,
    ) -> Result<&mut dyn ExecutionPlan> {
        let key = Self::key(backend, opts);
        match self.plans.entry(key) {
            Entry::Occupied(e) => {
                self.hits += 1;
                Ok(e.into_mut().as_mut())
            }
            Entry::Vacant(v) => {
                self.misses += 1;
                Ok(v.insert(backend.plan(opts)?).as_mut())
            }
        }
    }

    /// Plans served from the cache.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Plans built (first use of a key).
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Resident plan count.
    pub fn len(&self) -> usize {
        self.plans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.plans.is_empty()
    }

    /// Drop every resident plan (worker pools join on drop).
    pub fn clear(&mut self) {
        self.plans.clear();
    }

    /// The process-wide cache (plans survive across command invocations
    /// inside one process).
    pub fn global() -> &'static Mutex<PlanCache> {
        static GLOBAL: OnceLock<Mutex<PlanCache>> = OnceLock::new();
        GLOBAL.get_or_init(|| Mutex::new(PlanCache::new()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{
        AttnBatchRequest, AttnModule, AttnRequest, PlanScope, ReferenceBackend, SimBackend,
    };
    use crate::block::EncoderBlock;

    #[test]
    fn cache_hit_returns_the_resident_plan_and_outputs_stay_bit_identical() {
        let module = AttnModule::synthetic(12, 6, 2, 3, 5).unwrap();
        let backend = ReferenceBackend::new(module.clone());
        let mut cache = PlanCache::new();
        let opts = PlanOptions::default();
        let req = AttnBatchRequest::single(AttnRequest::new(module.random_input(4, 1).unwrap()));

        let cold = cache.get_or_plan(&backend, &opts).unwrap().run_batch(&req).unwrap();
        assert_eq!((cache.misses(), cache.hits(), cache.len()), (1, 0, 1));
        let warm = cache.get_or_plan(&backend, &opts).unwrap().run_batch(&req).unwrap();
        // the second lookup did NOT build a plan — it reused the resident one
        assert_eq!((cache.misses(), cache.hits(), cache.len()), (1, 1, 1));
        assert_eq!(
            cold.items[0].out_codes.as_ref().unwrap().codes.data,
            warm.items[0].out_codes.as_ref().unwrap().codes.data,
            "cold and warm outputs must be bit-identical"
        );
    }

    #[test]
    fn distinct_options_and_backends_get_distinct_entries() {
        let module = AttnModule::synthetic(12, 6, 2, 3, 5).unwrap();
        let r = ReferenceBackend::new(module.clone());
        let s = SimBackend::new(module);
        let mut cache = PlanCache::new();
        cache.get_or_plan(&r, &PlanOptions::default()).unwrap();
        cache.get_or_plan(&s, &PlanOptions::default()).unwrap();
        cache
            .get_or_plan(&r, &PlanOptions { workers: 3, ..PlanOptions::default() })
            .unwrap();
        assert_eq!((cache.misses(), cache.hits(), cache.len()), (3, 0, 3));
        cache.clear();
        assert!(cache.is_empty());
    }

    #[test]
    fn stacked_blocks_key_apart_by_label() {
        let mut a = EncoderBlock::synthetic(12, 24, 2, 3, 7).unwrap();
        let mut b = EncoderBlock::synthetic(12, 24, 2, 3, 8).unwrap();
        a.label = "block0".into();
        b.label = "block1".into();
        let opts = PlanOptions { scope: PlanScope::Block, ..PlanOptions::default() };
        let ka = PlanCache::key(&ReferenceBackend::for_block(a), &opts);
        let kb = PlanCache::key(&ReferenceBackend::for_block(b), &opts);
        assert_ne!(ka, kb, "same-geometry blocks must not collide: {ka}");
        // and scope is part of the key too
        let a2 = EncoderBlock::synthetic(12, 24, 2, 3, 7).unwrap();
        let k_attn =
            PlanCache::key(&ReferenceBackend::for_block(a2), &PlanOptions::default());
        assert_ne!(ka, k_attn);
    }
}
