//! [`ReferenceBackend`] — the bit-accurate golden reference: the
//! attention pipeline composed from [`crate::quant`] primitives
//! (`int_matmul`, `qlayernorm_comparator`, `qk_attention`) with scalar
//! epilogue loops. No hardware model, no cycle accounting — this is the
//! answer every other substrate must reproduce bit-for-bit.
//!
//! Planning ([`RefPlan`]) snapshots the folded module once; each batch
//! row then runs the same composition with no per-request setup.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::quant::layernorm::qlayernorm_comparator;
use crate::quant::linear::{int_matmul, IntMat};
use crate::quant::qtensor::{QTensor, QuantSpec, ScaleChain};
use crate::quant::round_half_even;
use crate::quant::softmax::qk_attention;

use crate::block::EncoderBlock;

use super::{
    ensure_plan_profile, AttnBatchRequest, AttnBatchResponse, AttnModule, AttnRequest,
    AttnResponse, Backend, Capabilities, ExecutionPlan, JobId, JobState, PlanOptions, PlanScope,
    StageCodes, SyncJobs,
};

/// The quant-composition reference execution path.
#[derive(Debug)]
pub struct ReferenceBackend {
    module: AttnModule,
    /// The encoder block this backend plans at [`PlanScope::Block`];
    /// `None` for attention-only backends.
    block: Option<EncoderBlock>,
}

impl ReferenceBackend {
    pub fn new(module: AttnModule) -> ReferenceBackend {
        ReferenceBackend { module, block: None }
    }

    /// A backend that can plan the whole encoder block (its attention
    /// half also serves [`PlanScope::Attention`] plans).
    pub fn for_block(block: EncoderBlock) -> ReferenceBackend {
        ReferenceBackend { module: block.attn.clone(), block: Some(block) }
    }

    pub fn module(&self) -> &AttnModule {
        &self.module
    }

    pub fn block(&self) -> Option<&EncoderBlock> {
        self.block.as_ref()
    }
}

fn check_input(module: &AttnModule, x: &QTensor) -> Result<()> {
    let want = module.input_spec();
    ensure!(x.cols() == module.d_in(), "input D {} != module {}", x.cols(), module.d_in());
    ensure!(
        x.spec.signed == want.signed && x.spec.bits == want.bits,
        "input spec {:?} does not match the module's {:?}",
        x.spec,
        want
    );
    let (got, exp) = (x.spec.step.get(), want.step.get());
    ensure!(
        (got - exp).abs() <= 1e-3 * exp.abs().max(got.abs()),
        "input step {got} does not match the module Δ̄_X {exp}"
    );
    Ok(())
}

/// `(acc + b̃_j) · scale_j` over an integer matmul — the Eq. 2 linear.
/// Loop shape (j outer, i inner) matches the simulator's post-scale
/// epilogue so fp results stay bit-identical across substrates.
fn linear_fp(
    x: &IntMat,
    folded: &crate::quant::fold::FoldedLinear,
    weight_scale_only: bool,
) -> Result<Vec<f32>> {
    let acc = int_matmul(x, &folded.codes)?;
    let n = folded.codes.rows;
    let mut out = vec![0f32; acc.rows * n];
    for j in 0..n {
        let scale = if weight_scale_only { folded.w_scale[j] } else { folded.out_scale[j] };
        for i in 0..acc.rows {
            out[i * n + j] = (acc.at(i, j) as f32 + folded.bias_folded[j]) * scale;
        }
    }
    Ok(out)
}

fn transpose(m: &IntMat) -> IntMat {
    let mut data = vec![0i32; m.rows * m.cols];
    for r in 0..m.rows {
        for c in 0..m.cols {
            data[c * m.rows + r] = m.at(r, c);
        }
    }
    IntMat::new(m.cols, m.rows, data)
}

/// One attention inference through the quant composition — the golden
/// reference every substrate must reproduce. Shared by the
/// single-request adapter, [`RefPlan::run_batch`] (so batch ≡ loop
/// bit-for-bit by construction) and the encoder-block composition
/// ([`crate::block::EncoderBlock::run_reference`]).
pub fn reference_attention(module: &AttnModule, x: &QTensor) -> Result<AttnResponse> {
    let t0 = Instant::now();
    check_input(module, x)?;
    let m = module;
    let (n, d) = (x.rows(), m.d_out());
    let dh = d / m.heads;
    let steps = &m.steps;

    // Q/K linears post-scaled by diag(Δ_W) only; V through its quantizer.
    let q_pre = linear_fp(&x.codes, &m.wq, true)?;
    let k_pre = linear_fp(&x.codes, &m.wk, true)?;
    let v_acc = int_matmul(&x.codes, &m.wv.codes)?;
    let v_spec = QuantSpec::signed(m.profile.v_proj, steps.s_v);
    let (v_min, v_max) = v_spec.range();
    let mut v_data = vec![0i32; n * d];
    for j in 0..d {
        // scales absorbed into the quantizer threshold (§IV-B)
        let eff = m.wv.out_scale[j] / steps.s_v.get();
        for i in 0..n {
            let v = (v_acc.at(i, j) as f32 + m.wv.bias_folded[j]) * eff;
            v_data[i * d + j] = (round_half_even(v) as i32).clamp(v_min, v_max);
        }
    }
    let v_codes = QTensor::new(IntMat::new(n, d, v_data), v_spec)?;

    // Quantizing LayerNorms (the Fig. 5 comparator identity), each
    // emitting codes at its own profile site.
    let ln = |x: &[f32], gamma: &[f32], beta: &[f32], step: f32, bits: u32| -> Vec<i32> {
        let mut out = vec![0i32; n * d];
        for r in 0..n {
            let c = qlayernorm_comparator(&x[r * d..(r + 1) * d], gamma, beta, step, bits, 1e-6);
            out[r * d..(r + 1) * d].copy_from_slice(&c);
        }
        out
    };
    let q_codes = QTensor::new(
        IntMat::new(
            n,
            d,
            ln(&q_pre, &m.lnq_gamma, &m.lnq_beta, steps.s_q.get(), m.profile.q_proj),
        ),
        QuantSpec::signed(m.profile.q_proj, steps.s_q),
    )?;
    let k_codes = QTensor::new(
        IntMat::new(
            n,
            d,
            ln(&k_pre, &m.lnk_gamma, &m.lnk_beta, steps.s_k.get(), m.profile.k_proj),
        ),
        QuantSpec::signed(m.profile.k_proj, steps.s_k),
    )?;

    // Per-head QKᵀ→softmax→quantize and attn·V requantization.
    let attn_spec = QuantSpec::unsigned(m.profile.attn_probs, steps.s_attn);
    let out_spec = QuantSpec::signed(m.profile.o_proj, steps.s_o);
    let (o_min, o_max) = out_spec.range();
    let eff_pv = ScaleChain::requant(steps.s_attn, steps.s_v, steps.s_o).eff();
    let mut pv = vec![0i32; n * d];
    let mut attn_head0 = None;
    for h in 0..m.heads {
        let qh = q_codes.slice_cols(h * dh, dh);
        let kh = k_codes.slice_cols(h * dh, dh);
        let vh = v_codes.slice_cols(h * dh, dh);
        let (attn, _scores) = qk_attention(
            &qh.codes,
            &kh.codes,
            steps.score.eff(),
            steps.s_attn.get(),
            m.profile.attn_probs,
            m.shift,
        )?;
        let acc = int_matmul(&attn, &transpose(&vh.codes))?;
        for i in 0..n {
            for j in 0..dh {
                pv[i * d + h * dh + j] =
                    (round_half_even(acc.at(i, j) as f32 * eff_pv) as i32).clamp(o_min, o_max);
            }
        }
        if h == 0 {
            attn_head0 = Some(QTensor::new(attn, attn_spec)?);
        }
    }
    let pv_mat = IntMat::new(n, d, pv);

    // W_O tail: full fp attention output (matches the pjrt artifact's
    // output boundary), Eq. 2 with Δ̄_X = Δ_O.
    let out_values = m.wo.as_ref().map(|wo| linear_fp(&pv_mat, wo, false)).transpose()?;

    Ok(AttnResponse {
        out_codes: Some(QTensor::new(pv_mat, out_spec)?),
        out_values,
        stages: Some(StageCodes {
            q: q_codes,
            k: k_codes,
            v: v_codes,
            attn_head0: attn_head0.expect("at least one head"),
        }),
        report: None,
        elapsed: t0.elapsed(),
    })
}

fn describe_module(m: &AttnModule) -> String {
    format!(
        "quant golden reference: D_in={} D_out={} heads={} bits[{}] ({}{})",
        m.d_in(),
        m.d_out(),
        m.heads,
        m.profile.key(),
        if m.shift { "shift-exp" } else { "exact-exp" },
        if m.wo.is_some() { ", W_O wired" } else { "" },
    )
}

/// The reference backend's execution plan: the folded module, snapshot
/// at plan time. Rows of a batch share it with no per-row rebinding.
/// Trivially synchronous: `submit` executes the batch inline and parks
/// the response for `poll`.
#[derive(Debug)]
pub struct RefPlan {
    module: AttnModule,
    jobs: SyncJobs<AttnBatchResponse>,
}

impl RefPlan {
    pub fn new(module: AttnModule) -> RefPlan {
        RefPlan { module, jobs: SyncJobs::new() }
    }

    fn execute(&self, req: &AttnBatchRequest) -> Result<AttnBatchResponse> {
        let t0 = Instant::now();
        let items = req
            .items
            .iter()
            .map(|r| reference_attention(&self.module, &r.x))
            .collect::<Result<Vec<_>>>()?;
        Ok(AttnBatchResponse { items, report: None, elapsed: t0.elapsed() })
    }
}

impl ExecutionPlan for RefPlan {
    fn backend_name(&self) -> &str {
        "ref"
    }

    fn describe(&self) -> String {
        describe_module(&self.module)
    }

    fn submit(&mut self, req: &AttnBatchRequest) -> Result<JobId> {
        let result = self.execute(req);
        Ok(self.jobs.push(result))
    }

    fn poll(&mut self, job: JobId) -> Result<JobState<AttnBatchResponse>> {
        self.jobs.poll(job, "ref plan")
    }
}

/// The reference backend's whole-block plan: each batch row runs the
/// encoder-block quant composition (LN → attention → +residual → LN →
/// MLP → +residual) and returns the block's output codes.
#[derive(Debug)]
pub struct RefBlockPlan {
    block: EncoderBlock,
    jobs: SyncJobs<AttnBatchResponse>,
}

impl RefBlockPlan {
    pub fn new(block: EncoderBlock) -> RefBlockPlan {
        RefBlockPlan { block, jobs: SyncJobs::new() }
    }

    fn execute(&self, req: &AttnBatchRequest) -> Result<AttnBatchResponse> {
        let t0 = Instant::now();
        let items = req
            .items
            .iter()
            .map(|r| {
                let row_t0 = Instant::now();
                let out = self.block.run_reference(&r.x)?;
                Ok(AttnResponse {
                    out_codes: Some(out),
                    out_values: None,
                    stages: None,
                    report: None,
                    elapsed: row_t0.elapsed(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(AttnBatchResponse { items, report: None, elapsed: t0.elapsed() })
    }
}

impl ExecutionPlan for RefBlockPlan {
    fn backend_name(&self) -> &str {
        "ref"
    }

    fn describe(&self) -> String {
        format!("quant golden reference, {}", self.block.describe())
    }

    fn submit(&mut self, req: &AttnBatchRequest) -> Result<JobId> {
        let result = self.execute(req);
        Ok(self.jobs.push(result))
    }

    fn poll(&mut self, job: JobId) -> Result<JobState<AttnBatchResponse>> {
        self.jobs.poll(job, "ref block plan")
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &str {
        "ref"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities { bit_exact_codes: true, hardware_stats: false, needs_artifacts: false }
    }

    fn describe(&self) -> String {
        match &self.block {
            Some(b) => format!("{} + {}", describe_module(&self.module), b.describe()),
            None => describe_module(&self.module),
        }
    }

    fn plan(&self, opts: &PlanOptions) -> Result<Box<dyn ExecutionPlan>> {
        match opts.scope {
            PlanScope::Attention => {
                ensure_plan_profile(&opts.profile, &self.module.profile, "ref attention module")?;
                Ok(Box::new(RefPlan::new(self.module.clone())))
            }
            PlanScope::Block => {
                let block = self.block.clone().ok_or_else(|| {
                    anyhow::anyhow!("ref backend was built without an encoder block (scope=Block)")
                })?;
                ensure_plan_profile(&opts.profile, &block.profile, "ref encoder block")?;
                Ok(Box::new(RefBlockPlan::new(block)))
            }
        }
    }

    /// Direct batch-of-one over the backend's own module — identical to
    /// `RefPlan::run_batch` row execution, without the per-call module
    /// snapshot the default adapter would take.
    fn run_attention(&mut self, req: &AttnRequest) -> Result<AttnResponse> {
        reference_attention(&self.module, &req.x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::BitProfile;

    #[test]
    fn reference_runs_and_reports_shapes() {
        let module = AttnModule::synthetic(16, 8, 2, BitProfile::uniform(3), 5).unwrap();
        let x = module.random_input(6, 3).unwrap();
        let mut b = ReferenceBackend::new(module);
        let resp = b.run_attention(&AttnRequest::new(x)).unwrap();
        let out = resp.out_codes.unwrap();
        assert_eq!((out.rows(), out.cols()), (6, 8));
        // W_O wired: the full fp output is emitted alongside the codes
        assert_eq!(resp.out_values.unwrap().len(), 6 * 8);
        let stages = resp.stages.unwrap();
        assert_eq!(stages.attn_head0.rows(), 6);
        assert!(resp.report.is_none());
        assert!(b.capabilities().bit_exact_codes);
        assert!(!b.capabilities().needs_artifacts);
    }

    #[test]
    fn rejects_wrong_input_spec() {
        let module = AttnModule::synthetic(16, 8, 2, BitProfile::uniform(3), 5).unwrap();
        let mut b = ReferenceBackend::new(module);
        let bad = QTensor::new(
            IntMat::new(2, 16, vec![0; 32]),
            QuantSpec::signed(4, crate::quant::Step::new(0.12).unwrap()),
        )
        .unwrap();
        assert!(b.run_attention(&AttnRequest::new(bad)).is_err());
    }

    #[test]
    fn block_scope_plans_run_the_whole_block() {
        use crate::backend::PlanScope;
        use crate::block::EncoderBlock;
        let block = EncoderBlock::synthetic(12, 24, 2, BitProfile::uniform(3), 31).unwrap();
        let x = block.random_input(4, 1).unwrap();
        let want = block.run_reference(&x).unwrap();
        let backend = ReferenceBackend::for_block(block);
        let opts = PlanOptions { scope: PlanScope::Block, ..PlanOptions::default() };
        let mut plan = backend.plan(&opts).unwrap();
        assert!(plan.describe().contains("encoder block"));
        let resp = plan.run_one(&AttnRequest::new(x)).unwrap();
        assert_eq!(resp.out_codes.unwrap().codes.data, want.codes.data);
        // a block backend still plans plain attention
        assert!(backend.plan(&PlanOptions::default()).is_ok());
        // attention-only backends refuse block scope — never a fallback
        let plain = ReferenceBackend::new(
            AttnModule::synthetic(12, 6, 2, BitProfile::uniform(3), 1).unwrap(),
        );
        assert!(plain.plan(&opts).is_err());
    }

    #[test]
    fn batch_of_three_equals_three_single_runs() {
        let module = AttnModule::synthetic(12, 6, 2, BitProfile::uniform(3), 17).unwrap();
        let reqs: Vec<AttnRequest> = (0..3)
            .map(|i| AttnRequest::new(module.random_input(4, 10 + i).unwrap()))
            .collect();
        let mut backend = ReferenceBackend::new(module.clone());
        let singles: Vec<AttnResponse> =
            reqs.iter().map(|r| backend.run_attention(r).unwrap()).collect();
        let mut plan = backend.plan(&PlanOptions::default()).unwrap();
        let batch = plan.run_batch(&AttnBatchRequest::new(reqs)).unwrap();
        assert_eq!(batch.items.len(), 3);
        for (a, b) in batch.items.iter().zip(&singles) {
            assert_eq!(
                a.out_codes.as_ref().unwrap().codes.data,
                b.out_codes.as_ref().unwrap().codes.data
            );
            assert_eq!(a.out_values, b.out_values);
        }
    }
}
