//! [`ReferenceBackend`] — the bit-accurate golden reference: the
//! attention pipeline composed from [`crate::quant`] primitives
//! (`int_matmul`, `qlayernorm_comparator`, `qk_attention`) with scalar
//! epilogue loops. No hardware model, no cycle accounting — this is the
//! answer every other substrate must reproduce bit-for-bit.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::quant::layernorm::qlayernorm_comparator;
use crate::quant::linear::{int_matmul, IntMat};
use crate::quant::qtensor::{QTensor, QuantSpec, ScaleChain};
use crate::quant::round_half_even;
use crate::quant::softmax::qk_attention;

use super::{AttnModule, AttnRequest, AttnResponse, Backend, Capabilities, StageCodes};

/// The quant-composition reference execution path.
#[derive(Debug)]
pub struct ReferenceBackend {
    module: AttnModule,
}

impl ReferenceBackend {
    pub fn new(module: AttnModule) -> ReferenceBackend {
        ReferenceBackend { module }
    }

    pub fn module(&self) -> &AttnModule {
        &self.module
    }

    fn check_input(&self, x: &QTensor) -> Result<()> {
        let want = self.module.input_spec();
        ensure!(x.cols() == self.module.d_in(), "input D {} != module {}", x.cols(), self.module.d_in());
        ensure!(
            x.spec.signed == want.signed && x.spec.bits == want.bits,
            "input spec {:?} does not match the module's {:?}",
            x.spec,
            want
        );
        let (got, exp) = (x.spec.step.get(), want.step.get());
        ensure!(
            (got - exp).abs() <= 1e-3 * exp.abs().max(got.abs()),
            "input step {got} does not match the module Δ̄_X {exp}"
        );
        Ok(())
    }

    /// `(acc + b̃_j) · scale_j` over an integer matmul — the Eq. 2 linear.
    fn linear_fp(
        x: &IntMat,
        folded: &crate::quant::fold::FoldedLinear,
        weight_scale_only: bool,
    ) -> Result<Vec<f32>> {
        let acc = int_matmul(x, &folded.codes)?;
        let n = folded.codes.rows;
        let mut out = vec![0f32; acc.rows * n];
        for j in 0..n {
            let scale = if weight_scale_only { folded.w_scale[j] } else { folded.out_scale[j] };
            for i in 0..acc.rows {
                out[i * n + j] = (acc.at(i, j) as f32 + folded.bias_folded[j]) * scale;
            }
        }
        Ok(out)
    }

    fn transpose(m: &IntMat) -> IntMat {
        let mut data = vec![0i32; m.rows * m.cols];
        for r in 0..m.rows {
            for c in 0..m.cols {
                data[c * m.rows + r] = m.at(r, c);
            }
        }
        IntMat::new(m.cols, m.rows, data)
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &str {
        "ref"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities { bit_exact_codes: true, hardware_stats: false, needs_artifacts: false }
    }

    fn describe(&self) -> String {
        let m = &self.module;
        format!(
            "quant golden reference: D_in={} D_out={} heads={} {}-bit (attn {}-bit, {})",
            m.d_in(),
            m.d_out(),
            m.heads,
            m.bits,
            m.attn_bits,
            if m.shift { "shift-exp" } else { "exact-exp" },
        )
    }

    fn run_attention(&mut self, req: &AttnRequest) -> Result<AttnResponse> {
        let t0 = Instant::now();
        self.check_input(&req.x)?;
        let m = &self.module;
        let (n, d) = (req.x.rows(), m.d_out());
        let dh = d / m.heads;
        let steps = &m.steps;

        // Q/K linears post-scaled by diag(Δ_W) only; V through its quantizer.
        let q_pre = Self::linear_fp(&req.x.codes, &m.wq, true)?;
        let k_pre = Self::linear_fp(&req.x.codes, &m.wk, true)?;
        let v_acc = int_matmul(&req.x.codes, &m.wv.codes)?;
        let v_spec = QuantSpec::signed(m.bits, steps.s_v);
        let (v_min, v_max) = v_spec.range();
        let mut v_data = vec![0i32; n * d];
        for j in 0..d {
            // scales absorbed into the quantizer threshold (§IV-B)
            let eff = m.wv.out_scale[j] / steps.s_v.get();
            for i in 0..n {
                let v = (v_acc.at(i, j) as f32 + m.wv.bias_folded[j]) * eff;
                v_data[i * d + j] = (round_half_even(v) as i32).clamp(v_min, v_max);
            }
        }
        let v_codes = QTensor::new(IntMat::new(n, d, v_data), v_spec)?;

        // Quantizing LayerNorms (the Fig. 5 comparator identity).
        let ln = |x: &[f32], gamma: &[f32], beta: &[f32], step: f32| -> Vec<i32> {
            let mut out = vec![0i32; n * d];
            for r in 0..n {
                let c = qlayernorm_comparator(&x[r * d..(r + 1) * d], gamma, beta, step, m.bits, 1e-6);
                out[r * d..(r + 1) * d].copy_from_slice(&c);
            }
            out
        };
        let q_codes = QTensor::new(
            IntMat::new(n, d, ln(&q_pre, &m.lnq_gamma, &m.lnq_beta, steps.s_q.get())),
            QuantSpec::signed(m.bits, steps.s_q),
        )?;
        let k_codes = QTensor::new(
            IntMat::new(n, d, ln(&k_pre, &m.lnk_gamma, &m.lnk_beta, steps.s_k.get())),
            QuantSpec::signed(m.bits, steps.s_k),
        )?;

        // Per-head QKᵀ→softmax→quantize and attn·V requantization.
        let attn_spec = QuantSpec::unsigned(m.attn_bits, steps.s_attn);
        let out_spec = QuantSpec::signed(m.bits, steps.s_o);
        let (o_min, o_max) = out_spec.range();
        let eff_pv = ScaleChain::requant(steps.s_attn, steps.s_v, steps.s_o).eff();
        let mut pv = vec![0i32; n * d];
        let mut attn_head0 = None;
        for h in 0..m.heads {
            let qh = q_codes.slice_cols(h * dh, dh);
            let kh = k_codes.slice_cols(h * dh, dh);
            let vh = v_codes.slice_cols(h * dh, dh);
            let (attn, _scores) = qk_attention(
                &qh.codes,
                &kh.codes,
                steps.score.eff(),
                steps.s_attn.get(),
                m.attn_bits,
                m.shift,
            )?;
            let acc = int_matmul(&attn, &Self::transpose(&vh.codes))?;
            for i in 0..n {
                for j in 0..dh {
                    pv[i * d + h * dh + j] =
                        (round_half_even(acc.at(i, j) as f32 * eff_pv) as i32).clamp(o_min, o_max);
                }
            }
            if h == 0 {
                attn_head0 = Some(QTensor::new(attn, attn_spec)?);
            }
        }

        Ok(AttnResponse {
            out_codes: Some(QTensor::new(IntMat::new(n, d, pv), out_spec)?),
            out_values: None,
            stages: Some(StageCodes {
                q: q_codes,
                k: k_codes,
                v: v_codes,
                attn_head0: attn_head0.expect("at least one head"),
            }),
            report: None,
            elapsed: t0.elapsed(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_runs_and_reports_shapes() {
        let module = AttnModule::synthetic(16, 8, 2, 3, 5).unwrap();
        let x = module.random_input(6, 3).unwrap();
        let mut b = ReferenceBackend::new(module);
        let resp = b.run_attention(&AttnRequest::new(x)).unwrap();
        let out = resp.out_codes.unwrap();
        assert_eq!((out.rows(), out.cols()), (6, 8));
        let stages = resp.stages.unwrap();
        assert_eq!(stages.attn_head0.rows(), 6);
        assert!(resp.report.is_none());
        assert!(b.capabilities().bit_exact_codes);
        assert!(!b.capabilities().needs_artifacts);
    }

    #[test]
    fn rejects_wrong_input_spec() {
        let module = AttnModule::synthetic(16, 8, 2, 3, 5).unwrap();
        let mut b = ReferenceBackend::new(module);
        let bad = QTensor::new(
            IntMat::new(2, 16, vec![0; 32]),
            QuantSpec::signed(4, crate::quant::Step::new(0.12).unwrap()),
        )
        .unwrap();
        assert!(b.run_attention(&AttnRequest::new(bad)).is_err());
    }
}
