//! [`PjrtBackend`] — the AOT-compiled Pallas attention artifact executed
//! through the PJRT runtime: the same integer codes in, the artifact's
//! fp attention output out (the exported graph dequantizes at its output
//! boundary, so this backend fills `out_values`, not `out_codes`).
//!
//! Requires `make artifacts`; construction fails with a clear message
//! otherwise, and the registry surfaces that to the CLI.

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use crate::runtime::Engine;
use crate::util::tensorio::{Data, Tensor};
use crate::util::Json;

use super::{
    AttnBatchRequest, AttnBatchResponse, AttnRequest, AttnResponse, Backend, Capabilities,
    ExecutionPlan, JobId, JobState, PlanOptions, QuantSpec, Step, SyncJobs,
};

/// The PJRT-executed Pallas-attention path.
pub struct PjrtBackend {
    engine: Engine,
    exe_name: String,
    artifacts: PathBuf,
    bits: u32,
    /// Attention-probability width the exported case declares
    /// (`attn_bits` in `attn_case/scalars.json`), when present — the
    /// one site of a plan profile allowed to differ from `bits`, and
    /// validated rather than trusted.
    case_attn_bits: Option<u32>,
    /// Input shape the artifact was lowered with ([tokens, dim]).
    input_shape: Vec<usize>,
    /// The quantizer spec the artifact's input codes were produced with
    /// (from the exported attn_case scalars, when present) — requests
    /// are validated against it rather than trusted.
    expected_spec: Option<QuantSpec>,
}

impl PjrtBackend {
    /// Load + compile the `attn_pallas` artifact for `bits`.
    pub fn load(artifacts: &Path, bits: u32) -> Result<PjrtBackend> {
        let mut engine = Engine::new(artifacts)?;
        let spec = engine
            .manifest
            .executables
            .iter()
            .find(|e| e.mode == "attn_pallas" && e.bits == bits)
            .ok_or_else(|| anyhow!("no attn_pallas executable for bits={bits} in the manifest"))?
            .clone();
        let exe_name = spec.name.clone();
        engine.load(&exe_name)?;
        let input_shape = spec
            .inputs
            .first()
            .map(|s| s.shape.clone())
            .ok_or_else(|| anyhow!("{exe_name}: spec has no inputs"))?;
        ensure!(input_shape.len() == 2, "{exe_name}: expected [tokens, dim] input, got {input_shape:?}");
        let (expected_spec, case_attn_bits) = read_case_scalars(artifacts)?;
        Ok(PjrtBackend {
            engine,
            exe_name,
            artifacts: artifacts.to_path_buf(),
            bits,
            case_attn_bits,
            input_shape,
            expected_spec,
        })
    }
}

/// The PJRT execution plan: a freshly bound engine + compiled
/// executable, owned by the plan so batches run with no per-request
/// artifact work. The artifact's lowered shape is per-request static,
/// so a batch executes as N device calls over the one bound executable.
/// Trivially synchronous: `submit` runs the device calls inline and
/// parks the response for `poll`.
pub struct PjrtPlan {
    inner: PjrtBackend,
    jobs: SyncJobs<AttnBatchResponse>,
}

impl PjrtPlan {
    fn execute(&mut self, req: &AttnBatchRequest) -> Result<AttnBatchResponse> {
        let t0 = Instant::now();
        let items = req
            .items
            .iter()
            .map(|r| self.inner.run_attention(r))
            .collect::<Result<Vec<_>>>()?;
        Ok(AttnBatchResponse { items, report: None, elapsed: t0.elapsed() })
    }
}

impl ExecutionPlan for PjrtPlan {
    fn backend_name(&self) -> &str {
        "pjrt"
    }

    fn describe(&self) -> String {
        self.inner.describe()
    }

    fn submit(&mut self, req: &AttnBatchRequest) -> Result<JobId> {
        let result = self.execute(req);
        Ok(self.jobs.push(result))
    }

    fn poll(&mut self, job: JobId) -> Result<JobState<AttnBatchResponse>> {
        self.jobs.poll(job, "pjrt plan")
    }
}

/// Read the exported Δ̄_X / bits / attn_bits from
/// `attn_case/scalars.json` (cheap — no tensor payloads), if the case
/// was exported alongside the HLO.
fn read_case_scalars(artifacts: &Path) -> Result<(Option<QuantSpec>, Option<u32>)> {
    let path = artifacts.join("attn_case").join("scalars.json");
    let Ok(text) = std::fs::read_to_string(&path) else {
        return Ok((None, None));
    };
    let j = Json::parse(&text)?;
    let attn_bits = j.get("attn_bits").and_then(Json::as_f64).map(|b| b as u32);
    match (j.get("sx").and_then(Json::as_f64), j.get("bits").and_then(Json::as_f64)) {
        (Some(sx), Some(bits)) => {
            Ok((Some(QuantSpec::signed(bits as u32, Step::new(sx as f32)?)), attn_bits))
        }
        _ => Ok((None, attn_bits)),
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &str {
        "pjrt"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities { bit_exact_codes: false, hardware_stats: false, needs_artifacts: true }
    }

    fn describe(&self) -> String {
        format!(
            "PJRT ({}) executing {} from {:?}, input {:?}",
            self.engine.platform(),
            self.exe_name,
            self.artifacts,
            self.input_shape,
        )
    }

    /// Plan-time work for PJRT is the artifact/engine binding: load and
    /// compile a fresh executable that the plan owns outright. The
    /// backend's engine is deliberately NOT shared into the plan: the
    /// PJRT handles are raw pointers with a single-thread contract (see
    /// the `unsafe impl Send` below), and a plan is routinely moved onto
    /// a coordinator worker thread while the backend stays behind —
    /// exclusive ownership is what keeps both sides sound, at the cost
    /// of one extra artifact load per plan.
    fn plan(&self, opts: &PlanOptions) -> Result<Box<dyn ExecutionPlan>> {
        ensure!(
            opts.scope == super::PlanScope::Attention,
            "the pjrt backend has no encoder-block artifact — block scope runs on ref/sim/sim-mt"
        );
        // The AOT artifact is lowered at ONE width; mixed per-site
        // profiles only exist on ref/sim/sim-mt. The exported case may
        // declare its own probability width, so `attn_probs` is the one
        // site allowed to differ — and it is validated against the
        // case's `attn_bits`, never silently overridden.
        let want_attn = self.case_attn_bits.unwrap_or(self.bits);
        ensure!(
            opts.profile.attn_probs == want_attn,
            "plan options request attn_probs:{} but the artifact's exported case runs \
             {want_attn}-bit attention probabilities",
            opts.profile.attn_probs
        );
        let mut base = opts.profile;
        base.attn_probs = self.bits;
        ensure!(
            base == super::BitProfile::uniform_checked(self.bits)?,
            "the pjrt backend supports only uniform bit profiles (artifact lowered at {} bits), \
             got [{}] — run mixed profiles on ref/sim/sim-mt",
            self.bits,
            opts.profile.key()
        );
        Ok(Box::new(PjrtPlan {
            inner: PjrtBackend::load(&self.artifacts, self.bits)?,
            jobs: SyncJobs::new(),
        }))
    }

    /// Direct single-request path — overrides the default plan-per-call
    /// adapter because planning compiles an engine.
    fn run_attention(&mut self, req: &AttnRequest) -> Result<AttnResponse> {
        let t0 = Instant::now();
        let (tokens, dim) = (self.input_shape[0], self.input_shape[1]);
        ensure!(
            req.x.rows() == tokens && req.x.cols() == dim,
            "input {}×{} does not match the artifact's static shape {}×{}",
            req.x.rows(),
            req.x.cols(),
            tokens,
            dim
        );
        if let Some(exp) = &self.expected_spec {
            ensure!(
                req.x.spec.signed == exp.signed && req.x.spec.bits == exp.bits,
                "input spec {:?} does not match the artifact's {:?}",
                req.x.spec,
                exp
            );
            let (got, want) = (req.x.spec.step.get(), exp.step.get());
            ensure!(
                (got - want).abs() <= 1e-3 * want.abs().max(got.abs()),
                "input step {got} does not match the artifact's exported Δ̄_X {want}"
            );
        }
        let exe = self
            .engine
            .get(&self.exe_name)
            .ok_or_else(|| anyhow!("executable dropped"))?;
        let t = Tensor {
            shape: self.input_shape.clone(),
            data: Data::I32(req.x.codes.data.clone()),
        };
        let out = exe.run(&[t])?;
        let values = out
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("no output"))?
            .as_f32()?
            .to_vec();
        Ok(AttnResponse {
            out_codes: None,
            out_values: Some(values),
            stages: None,
            report: None,
            elapsed: t0.elapsed(),
        })
    }
}

// PjRtClient/LoadedExecutable wrap heap pointers used from a single
// thread; callers move the whole backend onto one worker thread and
// never share it (same contract as coordinator::PjrtExecutor).
unsafe impl Send for PjrtBackend {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_fails_cleanly_without_artifacts() {
        let err = PjrtBackend::load(Path::new("/nonexistent-artifacts"), 3).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"), "{err:#}");
    }
}
