//! The unified execution API: one [`Backend`] trait over every
//! substrate that can run the paper's integerized attention —
//!
//! * [`ReferenceBackend`] — the bit-accurate [`crate::quant`] golden
//!   reference (scalar loops, no hardware model);
//! * [`SimBackend`] — the cycle-accounted systolic-array simulator
//!   ([`crate::sim`]), surfacing per-block [`BlockStats`] and energy;
//! * [`SimMtBackend`] — the same systolic model sharded across a fixed
//!   worker-thread pool (`sim-mt`): heads (and batch rows above a
//!   threshold) execute concurrently, with shard stats merged exactly;
//! * [`PjrtBackend`] — the AOT-compiled Pallas attention artifact
//!   executed through the PJRT runtime ([`crate::runtime`]).
//!
//! All backends consume the same [`AttnRequest`] and produce the same
//! [`AttnResponse`]; the paper's central claim — one computation graph,
//! bit-identical results on every substrate — becomes a trait-level
//! contract that `rust/tests/backend_parity.rs` enforces at DeiT-S
//! dimensions. Backends are looked up by name in a
//! [`BackendRegistry`] (`ref` | `sim` | `sim-mt` | `pjrt`), which is
//! what `ivit --backend`, the coordinator's
//! [`crate::coordinator::AttnBatchExecutor`] and the benches dispatch
//! through; future substrates (remote workers, GPU) plug into the same
//! seam.
//!
//! ## The plan/submit/poll lifecycle
//!
//! Execution is two-phase. **Planning** performs every piece of
//! per-module, per-deployment setup exactly once:
//! [`Backend::plan`]`(&PlanOptions) -> Box<dyn ExecutionPlan>` folds the
//! scale chains, lowers the module to its substrate (`to_sim` for the
//! simulators, engine/artifact binding for PJRT), sizes output buffers
//! and — for sharded plans — spawns the fixed worker pool. **Executing**
//! is a two-step job pipeline: [`ExecutionPlan::submit`] hands an
//! [`AttnBatchRequest`] of N rows to the plan and returns a [`JobId`]
//! immediately; [`ExecutionPlan::poll`] observes the job until it is
//! [`JobState::Done`] with the [`AttnBatchResponse`] (one
//! [`AttnResponse`] per row plus the merged hardware report). `ref`,
//! `sim` and `pjrt` are trivially synchronous — `submit` executes
//! inline and parks the response — while `sim-mt` is genuinely
//! overlapped: `submit` dispatches the batch's shards onto the worker
//! pool and returns while they run, so the coordinator can quantize and
//! submit batch N+1 while batch N is still in flight. Execution errors
//! surface at `poll`, never at `submit`, and a completed (or failed)
//! poll **consumes** the job — see [`job`] for the full contract.
//!
//! [`ExecutionPlan::run_batch`] remains as a submit-then-drain adapter
//! (blocking until the one job completes), so callers that want the
//! synchronous shape keep working unchanged; single-request
//! `run_attention` stays a batch-of-one adapter over it. The serving
//! stack ([`crate::coordinator::AttnBatchExecutor`], the CLI, the
//! benches) plans once and pipelines batches through submit/poll.
//!
//! A new backend therefore registers **two** things through one
//! [`BackendRegistry::register`] factory: the `Backend` (capabilities +
//! description + planning) and its `ExecutionPlan` (the batch executor).
//! See [`SimMtBackend`] for the canonical sharded example.
//!
//! ## Plan scope: attention vs whole encoder block
//!
//! [`PlanOptions::scope`] selects what each request row executes:
//! [`PlanScope::Attention`] (the paper's synthesized Fig. 2 module, the
//! default) or [`PlanScope::Block`] — one full
//! [`crate::block::EncoderBlock`] (LN → attention → +residual → LN →
//! MLP → +residual). Block plans consume the same [`AttnRequest`] /
//! [`AttnBatchRequest`] shapes (input codes in the *block's* input
//! spec) and return the block's output codes in `out_codes`; the
//! simulator plans merge MLP/residual/LN rows into the same
//! [`AttentionReport`]. Backends are given their block through
//! [`BackendConfig::block`] or the `for_block` constructors; planning
//! at block scope without one is an error, never a silent fallback.
//! Bit-identity across `ref`/`sim`/`sim-mt` extends to the whole block
//! (`tests/block_parity.rs`, DeiT-S dims, bits 2/3/4/8).
//!
//! Re-planning the same backend repeatedly (serve/simulate loops in one
//! process) can route through [`PlanCache`], which memoizes plans by
//! backend name + description + the **fully serialized** [`PlanOptions`].
//!
//! ## Per-module mixed precision ([`BitProfile`])
//!
//! Precision is not a scalar: every module carries a
//! [`crate::quant::BitProfile`] naming the width of each quantization
//! site (Q/K/V/O projections, the QKᵀ operands, the softmax·V operands,
//! FC1/FC2, the GELU-LUT boundary, the residual path).
//! [`PlanOptions::profile`] states the precision a plan must execute
//! at; integer backends validate it against their module/block at plan
//! time and the `pjrt` backend rejects non-uniform profiles (its AOT
//! artifact is lowered at one width). `BitProfile::uniform(b)` is the
//! legacy single-knob configuration and is pinned bit-identical to the
//! pre-profile stack; genuinely mixed profiles (e.g. `attn:4,mlp:8`)
//! run on `ref`/`sim`/`sim-mt` with ref ≡ sim parity and per-bit-width
//! energy/MAC splits in the merged report
//! ([`crate::sim::AttentionReport::macs_by_width`]).
//!
//! ## The typed-operand contract (`QTensor` / `ScaleChain`)
//!
//! Requests and responses never carry bare code buffers or raw `f32`
//! scales:
//!
//! * **[`QTensor`]** = integer codes + the [`QuantSpec`] (step Δ, bit
//!   width, signedness) that produced them. Constructors validate that
//!   every code lies in the spec's range; consumers (the linear arrays,
//!   the matmul quantizers, the backends) validate the spec against
//!   their folded constants instead of trusting the call site.
//! * **[`ScaleChain`]** = the explicit Eq. 2 folding algebra: an
//!   effective scale kept as `Π numerator / Π denominator` of named
//!   steps (e.g. `Δ_A·Δ_B/Δ_out` for the attn·V requantizer,
//!   `Δ_Q·Δ_K/√d` for the Eq. 3 score scale). `eff()` multiplies
//!   numerator terms in insertion order and divides once, so a chain
//!   built from the same steps is bit-identical to the hand-folded
//!   expression — checkpoint-imported pre-folded factors use
//!   [`ScaleChain::folded`].
//!
//! Every boundary that used to take `eff_scale: f32` or
//! `use_w_scale_only: bool` now takes these types; folding a scale
//! twice, skipping it, or dividing the wrong way no longer typechecks.

pub mod cache;
pub mod jit;
pub mod job;
pub mod pjrt;
pub mod reference;
pub mod registry;
pub mod sim;
pub mod sim_mt;

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::{anyhow, ensure, Result};

use crate::model::AttnCase;
use crate::quant::fold::{FoldedLinear, QuantParams};
use crate::quant::linear::IntMat;
use crate::sim::attention::{AttentionSim, AttentionSteps};
use crate::sim::layernorm::LayerNormSim;
use crate::sim::linear::LinearArraySim;
use crate::sim::AttentionReport;
use crate::util::{Json, XorShift};

pub use crate::quant::profile::BitProfile;
pub use crate::quant::qtensor::{QTensor, QuantSpec, ScaleChain, Step};
pub use cache::{PlanCache, PlanSeed};
pub use jit::JitBackend;
pub use job::{JobId, JobState, SyncJobs};
pub use pjrt::PjrtBackend;
pub use reference::ReferenceBackend;
pub use registry::{BackendConfig, BackendRegistry};
pub use sim::SimBackend;
pub use sim_mt::SimMtBackend;

/// What a backend can produce / requires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Capabilities {
    /// Produces integer output codes bit-identical to the quant reference.
    pub bit_exact_codes: bool,
    /// Surfaces per-block hardware stats / energy in the response.
    pub hardware_stats: bool,
    /// Requires AOT artifacts on disk.
    pub needs_artifacts: bool,
}

/// One attention inference over typed input codes.
#[derive(Debug, Clone)]
pub struct AttnRequest {
    /// Input activation codes, N×D, spec validated by the backend.
    pub x: QTensor,
}

impl AttnRequest {
    pub fn new(x: QTensor) -> AttnRequest {
        AttnRequest { x }
    }
}

/// Intermediate stage codes for cross-backend parity checks.
#[derive(Debug, Clone)]
pub struct StageCodes {
    pub q: QTensor,
    pub k: QTensor,
    pub v: QTensor,
    /// Head-0 attention probability codes.
    pub attn_head0: QTensor,
}

/// What a backend produced. Fields are optional per
/// [`Capabilities`]: integer backends fill `out_codes` + `stages`,
/// the PJRT artifact path fills `out_values`, the simulator adds
/// `report`.
#[derive(Debug)]
pub struct AttnResponse {
    /// Final attn·V output codes (N×D, step Δ_O).
    pub out_codes: Option<QTensor>,
    /// Fp output (backends whose artifact dequantizes at the boundary).
    pub out_values: Option<Vec<f32>>,
    /// Intermediate codes for parity checks.
    pub stages: Option<StageCodes>,
    /// Per-block hardware stats (Table I rows).
    pub report: Option<AttentionReport>,
    /// Wall-clock execution time.
    pub elapsed: Duration,
}

/// What a plan executes per request row: the self-attention module
/// alone (the paper's synthesized unit) or a whole encoder block
/// (LN → attention → +residual → LN → MLP → +residual, the
/// [`crate::block::EncoderBlock`] composition). Block-scope planning
/// requires the backend to have been built with a block (see
/// [`BackendConfig::block`] / the `for_block` constructors).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlanScope {
    /// Attention-only (Fig. 2): the original request unit.
    #[default]
    Attention,
    /// Full encoder block: MLP and residual requantization included.
    Block,
}

impl PlanScope {
    /// Stable serialized name (`plan_cache.json`, options keys).
    pub fn as_str(self) -> &'static str {
        match self {
            PlanScope::Attention => "attention",
            PlanScope::Block => "block",
        }
    }

    /// Parse a serialized scope name.
    pub fn parse(s: &str) -> Result<PlanScope> {
        match s {
            "attention" => Ok(PlanScope::Attention),
            "block" => Ok(PlanScope::Block),
            other => Err(anyhow!("unknown plan scope '{other}'")),
        }
    }
}

/// One-time execution-setup knobs consumed by [`Backend::plan`].
///
/// Precision is a first-class option: [`Self::profile`] names the
/// per-site [`BitProfile`] the plan must execute at. Backends validate
/// it against the module/block they were built from (a mismatch is a
/// loud planning error, never a silent re-quantization), and the
/// serialized form of the *whole* options struct — profile included —
/// is what [`PlanCache`] keys plans by, so two deployments differing
/// only in precision can never alias.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanOptions {
    /// Worker threads for sharded plans (`sim-mt`). `0` = the backend's
    /// own default (its configured count, else available parallelism).
    pub workers: usize,
    /// Batch size at or above which sharded plans also split the
    /// per-row front stage across workers (heads always shard).
    pub row_shard_threshold: usize,
    /// What each request row executes: attention only, or the whole
    /// encoder block.
    pub scope: PlanScope,
    /// The per-site precision the plan executes at.
    pub profile: BitProfile,
}

impl Default for PlanOptions {
    fn default() -> Self {
        PlanOptions {
            workers: 0,
            row_shard_threshold: 2,
            scope: PlanScope::Attention,
            profile: BitProfile::uniform(3),
        }
    }
}

impl PlanOptions {
    /// Default options at a given precision profile.
    pub fn for_profile(profile: BitProfile) -> PlanOptions {
        PlanOptions { profile, ..PlanOptions::default() }
    }

    /// The full serialized form — every field, nothing hand-picked.
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("workers".to_string(), Json::Num(self.workers as f64));
        obj.insert(
            "row_shard_threshold".to_string(),
            Json::Num(self.row_shard_threshold as f64),
        );
        obj.insert("scope".to_string(), Json::Str(self.scope.as_str().to_string()));
        obj.insert("profile".to_string(), self.profile.to_json());
        Json::Obj(obj)
    }

    /// Parse the serialized form; missing or corrupt fields (including
    /// a truncated profile) are loud errors.
    pub fn from_json(j: &Json) -> Result<PlanOptions> {
        let num = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("plan options: missing numeric field '{k}'"))
        };
        Ok(PlanOptions {
            workers: num("workers")? as usize,
            row_shard_threshold: num("row_shard_threshold")? as usize,
            scope: PlanScope::parse(
                j.get("scope")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("plan options: missing 'scope'"))?,
            )?,
            profile: BitProfile::from_json(
                j.get("profile").ok_or_else(|| anyhow!("plan options: missing 'profile'"))?,
            )?,
        })
    }

    /// Canonical cache-key fragment: the deterministic rendering of the
    /// FULL serialized options (BTreeMap ordering), so every field —
    /// profile included — keys plans apart.
    pub fn key(&self) -> String {
        self.to_json().to_string()
    }

    /// Short human rendering for logs and errors: scope, workers and the
    /// full profile key — po2 suffixes included, so two plans differing
    /// only in scale mode never read alike.
    pub fn describe(&self) -> String {
        let workers = if self.workers == 0 { "auto".to_string() } else { self.workers.to_string() };
        format!("scope={} workers={workers} profile=[{}]", self.scope.as_str(), self.profile.key())
    }
}

/// Validate that the profile a caller planned with matches the profile
/// the backend's module/block actually carries.
pub(crate) fn ensure_plan_profile(
    requested: &BitProfile,
    actual: &BitProfile,
    what: &str,
) -> Result<()> {
    if requested == actual {
        return Ok(());
    }
    // same widths, po2-only mismatch: call the real hazard out — a
    // shift-only plan cannot execute free-scale folded constants (its
    // scale chains were never snapped), and a free-scale plan silently
    // forfeits the shift datapath the caller asked for
    if requested.strip_po2() == actual.strip_po2() {
        return Err(anyhow!(
            "plan options request bit profile [{}] but the {what} was built at [{}] — the \
             widths agree but the po2 scale modes differ; build the backend and the plan \
             options from the same :po2 profile",
            requested.key(),
            actual.key()
        ));
    }
    Err(anyhow!(
        "plan options request bit profile [{}] but the {what} was built at [{}] — \
         construct the backend and the plan options from the same profile",
        requested.key(),
        actual.key()
    ))
}

/// A batch of attention inferences over one planned module.
#[derive(Debug, Clone, Default)]
pub struct AttnBatchRequest {
    pub items: Vec<AttnRequest>,
}

impl AttnBatchRequest {
    pub fn new(items: Vec<AttnRequest>) -> AttnBatchRequest {
        AttnBatchRequest { items }
    }

    pub fn single(req: AttnRequest) -> AttnBatchRequest {
        AttnBatchRequest { items: vec![req] }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

/// What a plan produced for a batch: one [`AttnResponse`] per request
/// row (same order), plus the batch-merged hardware report for plans
/// whose substrate surfaces stats (shard counters add exactly, so
/// `report.total_macs()` equals the sum over rows/shards).
#[derive(Debug)]
pub struct AttnBatchResponse {
    pub items: Vec<AttnResponse>,
    /// Merged per-block stats over every row and shard of the batch.
    pub report: Option<AttentionReport>,
    /// Wall-clock time of the whole batch. Per-item `elapsed` fields of
    /// concurrent plans are this wall time amortized over the rows.
    pub elapsed: Duration,
}

/// The per-batch execution half of the plan/submit/poll API.
///
/// A plan owns everything execution needs — folded scales, lowered
/// simulators, bound PJRT executables, worker pools — so executing a
/// batch performs no per-request setup. Plans are `Send` (the
/// coordinator moves them onto its worker thread) but single-owner:
/// every execution method takes `&mut self`.
///
/// Execution is a job pipeline: [`Self::submit`] accepts a batch and
/// returns a [`JobId`] without waiting for the result; [`Self::poll`]
/// observes it until [`JobState::Done`]. Synchronous substrates run the
/// batch inside `submit`; `sim-mt` dispatches shards and keeps
/// accepting new submissions while earlier jobs are in flight. The
/// blocking [`Self::run_batch`] adapter (submit, then drain one job)
/// serves callers that don't pipeline.
pub trait ExecutionPlan: Send {
    /// Registry name of the backend that planned this, e.g. `"sim-mt"`.
    fn backend_name(&self) -> &str;

    /// One-line human description (dims, substrate, shard layout).
    fn describe(&self) -> String;

    /// Accept N rows for execution and return a job handle immediately.
    /// Errors only when the job cannot be accepted (e.g. the worker
    /// pool is gone) — execution failures surface at [`Self::poll`].
    fn submit(&mut self, req: &AttnBatchRequest) -> Result<JobId>;

    /// Observe a submitted job. `Done` (and any execution error)
    /// consumes the job: polling the same id again, or an id this plan
    /// never issued, is an error — never `Pending`.
    fn poll(&mut self, job: JobId) -> Result<JobState<AttnBatchResponse>>;

    /// Adapter: submit one batch and drain it to completion.
    fn run_batch(&mut self, req: &AttnBatchRequest) -> Result<AttnBatchResponse> {
        let job = self.submit(req)?;
        loop {
            match self.poll(job)? {
                JobState::Done(resp) => return Ok(resp),
                // concurrent plans finish on their own workers; yield
                // the caller thread briefly instead of spinning hot
                JobState::Pending => std::thread::sleep(Duration::from_micros(50)),
            }
        }
    }

    /// Adapter: run a single request as a batch of one.
    fn run_one(&mut self, req: &AttnRequest) -> Result<AttnResponse> {
        let mut resp = self.run_batch(&AttnBatchRequest::single(req.clone()))?;
        resp.items.pop().ok_or_else(|| anyhow!("{}: empty batch response", self.backend_name()))
    }
}

/// The uniform execution interface over all substrates.
///
/// `Send` is required so a backend can be moved onto a coordinator
/// worker thread (the PJRT implementation is move-only single-threaded,
/// like [`crate::coordinator::PjrtExecutor`]).
pub trait Backend: Send {
    /// Registry name, e.g. `"ref"`.
    fn name(&self) -> &str;

    /// What this backend can produce / requires.
    fn capabilities(&self) -> Capabilities;

    /// One-line human description (dims, substrate, artifact source).
    fn describe(&self) -> String;

    /// Perform all one-time setup (scale folding, substrate lowering,
    /// artifact/engine binding, buffer sizing, worker-pool spawn) and
    /// return the batch executor.
    fn plan(&self, opts: &PlanOptions) -> Result<Box<dyn ExecutionPlan>>;

    /// Execute one attention inference. Default adapter: plan with
    /// `PlanOptions::default()` — which carries the default
    /// `BitProfile::uniform(3)` — then run a batch of one. Backends
    /// whose module is at any other profile MUST override this (all
    /// built-ins do, with resident-plan paths that also keep repeated
    /// single requests amortized); otherwise the adapter's plan-time
    /// profile validation rejects the mismatch.
    fn run_attention(&mut self, req: &AttnRequest) -> Result<AttnResponse> {
        self.plan(&PlanOptions::default())?.run_one(req)
    }
}

/// The integerized attention-module parameters every backend consumes:
/// folded linears, LayerNorm constants, and the typed quantizer steps.
/// Precision is carried by the [`BitProfile`]'s attention sites:
/// `attn_x` (input codes), `q_proj`/`k_proj`/`v_proj`/`o_proj`
/// (projection weights + their output code streams) and `attn_probs`
/// (the unsigned softmax codes).
#[derive(Debug, Clone)]
pub struct AttnModule {
    pub wq: FoldedLinear,
    pub wk: FoldedLinear,
    pub wv: FoldedLinear,
    /// The attention output projection W_O, folded with Δ̄_X = Δ_O.
    /// When present, integer backends emit the full fp attention output
    /// (`out_values`) the pjrt artifact emits, alongside the PV codes.
    /// `None` for paper-geometry modules (Table I stops at PV).
    pub wo: Option<FoldedLinear>,
    pub lnq_gamma: Vec<f32>,
    pub lnq_beta: Vec<f32>,
    pub lnk_gamma: Vec<f32>,
    pub lnk_beta: Vec<f32>,
    pub steps: AttentionSteps,
    /// The module input step Δ̄_X (what the projections were folded with).
    pub s_x: Step,
    pub heads: usize,
    /// Per-site precision assignment.
    pub profile: BitProfile,
    /// Eq. 4 shift exponential (false = exact-exp ablation).
    pub shift: bool,
}

impl AttnModule {
    /// Input dimension (K of the projections).
    pub fn d_in(&self) -> usize {
        self.wq.codes.cols
    }

    /// Projection output dimension (D = heads · head_dim).
    pub fn d_out(&self) -> usize {
        self.wq.codes.rows
    }

    /// The quantizer spec input activations must carry.
    pub fn input_spec(&self) -> QuantSpec {
        QuantSpec::signed(self.profile.attn_x, self.s_x)
    }

    /// Build the systolic simulator for this module. Each projection
    /// array streams `attn_x`-wide activations over its own site's
    /// weight width; W_O streams the `o_proj` PV codes.
    pub fn to_sim(&self) -> AttentionSim {
        let p = &self.profile;
        AttentionSim {
            wq: LinearArraySim::new_split("Q linear", self.wq.clone(), p.attn_x, p.q_proj),
            wk: LinearArraySim::new_split("K linear", self.wk.clone(), p.attn_x, p.k_proj),
            wv: LinearArraySim::new_split("V linear", self.wv.clone(), p.attn_x, p.v_proj)
                .with_po2_requant(p.po2_mode("v_proj").map(|m| m.is_po2()).unwrap_or(false)),
            wo: self
                .wo
                .as_ref()
                .map(|f| LinearArraySim::new_split("O linear", f.clone(), p.o_proj, p.o_proj)),
            lnq: LayerNormSim::new(
                "Q LayerNorm",
                self.lnq_gamma.clone(),
                self.lnq_beta.clone(),
                self.steps.s_q.get(),
                p.q_proj,
            ),
            lnk: LayerNormSim::new(
                "K LayerNorm",
                self.lnk_gamma.clone(),
                self.lnk_beta.clone(),
                self.steps.s_k.get(),
                p.k_proj,
            ),
            steps: self.steps.clone(),
            heads: self.heads,
            profile: self.profile,
            shift: self.shift,
        }
    }

    /// Load the module from an exported cross-language attention case
    /// (uniform per-site widths, with the exported probability width on
    /// the `attn_probs` site).
    pub fn from_case(case: &AttnCase, shift: bool) -> Result<AttnModule> {
        let fold = |l: &crate::model::attn_case::CaseLinear| FoldedLinear {
            codes: l.codes.clone(),
            bias_folded: l.bias_folded.clone(),
            w_scale: l.w_scale.clone(),
            out_scale: l.out_scale.clone(),
        };
        let mut profile = BitProfile::uniform_checked(case.bits)?;
        profile.set_site("attn_probs", case.attn_bits)?;
        Ok(AttnModule {
            wq: fold(&case.wq),
            wk: fold(&case.wk),
            wv: fold(&case.wv),
            wo: Some(fold(&case.wo)),
            lnq_gamma: case.lnq_g.clone(),
            lnq_beta: case.lnq_b.clone(),
            lnk_gamma: case.lnk_g.clone(),
            lnk_beta: case.lnk_b.clone(),
            steps: AttentionSteps {
                s_q: Step::new(case.s_q)?,
                s_k: Step::new(case.s_k)?,
                s_v: Step::new(case.s_v)?,
                s_attn: Step::new(case.s_attn)?,
                s_o: Step::new(case.s_o)?,
                // imported pre-folded for bit-exact replay of the export
                score: ScaleChain::folded(case.score_scale),
            },
            s_x: Step::new(case.sx)?,
            heads: case.heads,
            profile,
            shift,
        })
    }

    /// Deterministic single-head module at the paper's Table I geometry
    /// parameters (uniform steps, identity LayerNorm) — what
    /// [`AttentionSim::paper_geometry`] instantiates. Table I is a
    /// uniform-precision artifact, so this takes plain `bits`.
    pub fn paper_shape(d_in: usize, d_head: usize, bits: u32) -> Result<AttnModule> {
        let profile = BitProfile::uniform_checked(bits)?;
        let mut rng = XorShift::new(1);
        let mut mk = |_name: &str| -> Result<FoldedLinear> {
            let w: Vec<f32> = rng.normal_vec(d_head * d_in).iter().map(|v| v * 0.1).collect();
            let bias = vec![0.0f32; d_head];
            let step_w = vec![0.05f32; d_head];
            FoldedLinear::fold(&w, d_head, d_in, &bias, &QuantParams { bits, step_x: 0.1, step_w })
        };
        let (wq, wk, wv) = (mk("q")?, mk("k")?, mk("v")?);
        let s_q = Step::new(0.4)?;
        let s_k = Step::new(0.4)?;
        Ok(AttnModule {
            wq,
            wk,
            wv,
            // Table I geometry stops at the PV matmul — no W_O row.
            wo: None,
            lnq_gamma: vec![1.0; d_head],
            lnq_beta: vec![0.0; d_head],
            lnk_gamma: vec![1.0; d_head],
            lnk_beta: vec![0.0; d_head],
            steps: AttentionSteps {
                s_q,
                s_k,
                s_v: Step::new(0.1)?,
                s_attn: Step::new(1.0 / ((1u32 << profile.attn_probs) - 1) as f32)?,
                s_o: Step::new(0.1)?,
                score: ScaleChain::scores(s_q, s_k, d_head),
            },
            s_x: Step::new(0.1)?,
            heads: 1,
            profile,
            shift: true,
        })
    }

    /// Randomised multi-head module for parity / stress testing: varied
    /// weights, biases, per-channel steps and LayerNorm affines. Each
    /// projection folds its weights at its own profile site.
    pub fn synthetic(
        d_in: usize,
        d_out: usize,
        heads: usize,
        profile: BitProfile,
        seed: u64,
    ) -> Result<AttnModule> {
        ensure!(heads > 0 && d_out % heads == 0, "d_out {d_out} must divide into {heads} heads");
        profile.validate()?;
        let mut rng = XorShift::new(seed);
        // Each quantizer step is owned by one profile site; po2 sites
        // snap their step at construction (see crate::quant::po2). The
        // RNG draw order is identical for free and po2 profiles, so
        // free-scale modules stay byte-identical to the pre-po2 stack.
        let s_x = Step::new(0.12)?.snap_for(profile.po2_mode("attn_x")?)?;
        let step_x = s_x.get();
        let mut mk = |site: &str| -> Result<FoldedLinear> {
            let bits = profile.site(site)?;
            let mode = profile.po2_mode(site)?;
            let w: Vec<f32> = rng.normal_vec(d_out * d_in).iter().map(|v| v * 0.15).collect();
            let bias: Vec<f32> = rng.normal_vec(d_out).iter().map(|v| v * 0.5).collect();
            let step_w: Vec<f32> = (0..d_out).map(|_| rng.uniform(0.03, 0.15) as f32).collect();
            FoldedLinear::fold_site(
                &w,
                d_out,
                d_in,
                &bias,
                &QuantParams { bits, step_x, step_w },
                mode,
            )
        };
        let (wq, wk, wv) = (mk("q_proj")?, mk("k_proj")?, mk("v_proj")?);
        let gamma: Vec<f32> = (0..d_out).map(|_| rng.uniform(0.5, 1.5) as f32).collect();
        let beta: Vec<f32> = rng.normal_vec(d_out).iter().map(|v| v * 0.2).collect();
        let s_q = Step::new(0.5)?.snap_for(profile.po2_mode("q_proj")?)?;
        let s_k = Step::new(0.5)?.snap_for(profile.po2_mode("k_proj")?)?;
        let s_o = Step::new(0.1)?.snap_for(profile.po2_mode("o_proj")?)?;
        // W_O: D→D projection folded with Δ̄_X = Δ_O (its operands are
        // the PV output codes).
        let wo = {
            let w: Vec<f32> = rng.normal_vec(d_out * d_out).iter().map(|v| v * 0.15).collect();
            let bias: Vec<f32> = rng.normal_vec(d_out).iter().map(|v| v * 0.5).collect();
            let step_w: Vec<f32> = (0..d_out).map(|_| rng.uniform(0.03, 0.15) as f32).collect();
            FoldedLinear::fold_site(
                &w,
                d_out,
                d_out,
                &bias,
                &QuantParams { bits: profile.o_proj, step_x: s_o.get(), step_w },
                profile.po2_mode("o_proj")?,
            )?
        };
        Ok(AttnModule {
            wq,
            wk,
            wv,
            wo: Some(wo),
            lnq_gamma: gamma.clone(),
            lnq_beta: beta.clone(),
            lnk_gamma: gamma,
            lnk_beta: beta,
            steps: AttentionSteps {
                s_q,
                s_k,
                s_v: Step::new(0.1)?.snap_for(profile.po2_mode("v_proj")?)?,
                s_attn: Step::new(1.0 / ((1u32 << profile.attn_probs) - 1) as f32)?
                    .snap_for(profile.po2_mode("attn_probs")?)?,
                s_o,
                score: ScaleChain::scores(s_q, s_k, d_out / heads),
            },
            s_x,
            heads,
            profile,
            shift: true,
        })
    }

    /// Random input codes (`tokens` × `d_in`) in this module's input spec.
    pub fn random_input(&self, tokens: usize, seed: u64) -> Result<QTensor> {
        let spec = self.input_spec();
        let (qmin, qmax) = spec.range();
        let mut rng = XorShift::new(seed);
        QTensor::new(
            IntMat::new(tokens, self.d_in(), rng.codes(tokens * self.d_in(), qmin, qmax)),
            spec,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn module_shapes_and_spec() {
        let m = AttnModule::synthetic(16, 8, 2, BitProfile::uniform(3), 9).unwrap();
        assert_eq!(m.d_in(), 16);
        assert_eq!(m.d_out(), 8);
        assert_eq!(m.input_spec().bits, 3);
        assert!(m.input_spec().signed);
        let x = m.random_input(5, 1).unwrap();
        assert_eq!((x.rows(), x.cols()), (5, 16));
        assert!(AttnModule::synthetic(16, 9, 2, BitProfile::uniform(3), 9).is_err());
    }

    #[test]
    fn to_sim_runs() {
        let m = AttnModule::synthetic(12, 6, 1, BitProfile::uniform(3), 11).unwrap();
        let x = m.random_input(4, 2).unwrap();
        let out = m.to_sim().run(&x).unwrap();
        assert_eq!((out.pv_codes.rows(), out.pv_codes.cols()), (4, 6));
    }

    #[test]
    fn plan_options_serde_round_trips_and_keys_profiles_apart() {
        let mixed = PlanOptions {
            workers: 4,
            row_shard_threshold: 3,
            scope: PlanScope::Block,
            profile: BitProfile::parse("attn:4,mlp:8").unwrap(),
        };
        for opts in [PlanOptions::default(), mixed.clone()] {
            let text = format!("{}", opts.to_json());
            let back = PlanOptions::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, opts, "round trip through {text}");
        }
        // the serialized key separates options differing ONLY in profile
        let a = PlanOptions::for_profile(BitProfile::uniform(4));
        let b = PlanOptions::for_profile(BitProfile::parse("attn:4,mlp:8").unwrap());
        assert_ne!(a.key(), b.key());
        // a corrupt profile inside serialized options is a loud error
        let mut obj = match mixed.to_json() {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        obj.insert("profile".into(), Json::Str("not a profile".into()));
        assert!(PlanOptions::from_json(&Json::Obj(obj)).is_err());
    }

    #[test]
    fn mixed_profile_module_folds_per_site() {
        let profile = BitProfile::parse("attn_x:8,q_proj:2,k_proj:3,v_proj:4,o_proj:8,attn_probs:4")
            .unwrap();
        let m = AttnModule::synthetic(12, 6, 2, profile, 13).unwrap();
        assert_eq!(m.input_spec().bits, 8);
        // each projection's weight codes live in its own site range
        let max_code = |f: &FoldedLinear| f.codes.data.iter().map(|c| c.abs()).max().unwrap();
        assert!(max_code(&m.wq) <= 2, "2-bit Q weights");
        assert!(max_code(&m.wk) <= 4, "3-bit K weights");
        assert!(max_code(&m.wv) <= 8, "4-bit V weights");
        // and the sim runs end to end at the mixed widths
        let x = m.random_input(4, 2).unwrap();
        let out = m.to_sim().run(&x).unwrap();
        assert_eq!(out.pv_codes.spec.bits, 8);
        assert_eq!(out.attn_codes[0].spec.bits, 4);
    }
}
