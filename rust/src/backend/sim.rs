//! [`SimBackend`] — the cycle-accounted systolic-array substrate: wraps
//! [`crate::sim::AttentionSim`] behind the [`Backend`] trait, surfacing
//! the per-block [`crate::sim::BlockStats`] rows (Table I) and energy in
//! every response. Integer outputs are bit-identical to
//! [`super::ReferenceBackend`] (enforced by the cross-backend parity
//! suite).

use std::time::Instant;

use anyhow::Result;

use super::{AttnModule, AttnRequest, AttnResponse, Backend, Capabilities, StageCodes};
use crate::sim::attention::AttentionSim;
use crate::sim::EnergyModel;

/// The systolic-array simulator execution path.
#[derive(Debug)]
pub struct SimBackend {
    module: AttnModule,
    sim: AttentionSim,
    energy: EnergyModel,
}

impl SimBackend {
    pub fn new(module: AttnModule) -> SimBackend {
        let sim = module.to_sim();
        SimBackend { module, sim, energy: EnergyModel::default() }
    }

    pub fn module(&self) -> &AttnModule {
        &self.module
    }

    /// The energy model used for power summaries in [`Self::describe`].
    pub fn energy_model(&self) -> &EnergyModel {
        &self.energy
    }
}

impl Backend for SimBackend {
    fn name(&self) -> &str {
        "sim"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities { bit_exact_codes: true, hardware_stats: true, needs_artifacts: false }
    }

    fn describe(&self) -> String {
        let m = &self.module;
        format!(
            "systolic-array simulator: D_in={} D_out={} heads={} {}-bit (attn {}-bit, {}), activity-based energy model",
            m.d_in(),
            m.d_out(),
            m.heads,
            m.bits,
            m.attn_bits,
            if m.shift { "shift-exp" } else { "exact-exp" },
        )
    }

    fn run_attention(&mut self, req: &AttnRequest) -> Result<AttnResponse> {
        let t0 = Instant::now();
        let out = self.sim.run(&req.x)?;
        Ok(AttnResponse {
            out_codes: Some(out.pv_codes),
            out_values: None,
            stages: Some(StageCodes {
                q: out.q_codes,
                k: out.k_codes,
                v: out.v_codes,
                attn_head0: out.attn_codes.into_iter().next().expect("at least one head"),
            }),
            report: Some(out.report),
            elapsed: t0.elapsed(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::AttnRequest;

    #[test]
    fn sim_backend_surfaces_hardware_stats() {
        let module = AttnModule::synthetic(16, 8, 2, 3, 5).unwrap();
        let x = module.random_input(6, 3).unwrap();
        let mut b = SimBackend::new(module);
        assert!(b.capabilities().hardware_stats);
        let resp = b.run_attention(&AttnRequest::new(x)).unwrap();
        let report = resp.report.expect("sim surfaces BlockStats");
        assert!(report.total_macs() > 0);
        assert!(report.total_power_w(b.energy_model()) > 0.0);
        assert!(resp.out_codes.is_some());
    }
}
