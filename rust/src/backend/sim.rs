//! [`SimBackend`] — the cycle-accounted systolic-array substrate: wraps
//! [`crate::sim::AttentionSim`] behind the [`Backend`] trait, surfacing
//! the per-block [`crate::sim::BlockStats`] rows (Table I) and energy in
//! every response. Integer outputs are bit-identical to
//! [`super::ReferenceBackend`] (enforced by the cross-backend parity
//! suite).
//!
//! Planning ([`SimPlan`]) performs the module→simulator lowering
//! (`to_sim`: folded-constant binding, per-block array construction)
//! once; `run_batch` then streams rows through the pre-built arrays and
//! merges the per-row reports.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::block::EncoderBlock;

use super::{
    ensure_plan_profile, AttnBatchRequest, AttnBatchResponse, AttnModule, AttnResponse, Backend,
    Capabilities, ExecutionPlan, JobId, JobState, PlanOptions, PlanScope, StageCodes, SyncJobs,
};
use crate::sim::attention::{AttentionOutput, AttentionSim};
use crate::sim::block::BlockSim;
use crate::sim::{AttentionReport, EnergyModel};

/// The systolic-array simulator execution path.
#[derive(Debug)]
pub struct SimBackend {
    module: AttnModule,
    /// The encoder block this backend plans at [`PlanScope::Block`].
    block: Option<EncoderBlock>,
    /// The backend's own resident plan, built once at construction so
    /// direct `run_attention` calls stay amortized (no re-lowering).
    resident: SimPlan,
    energy: EnergyModel,
}

impl SimBackend {
    pub fn new(module: AttnModule) -> SimBackend {
        let resident = SimPlan::new(&module);
        SimBackend { module, block: None, resident, energy: EnergyModel::default() }
    }

    /// A backend that can plan the whole encoder block (its attention
    /// half also serves [`PlanScope::Attention`] plans).
    pub fn for_block(block: EncoderBlock) -> SimBackend {
        let module = block.attn.clone();
        let resident = SimPlan::new(&module);
        SimBackend { module, block: Some(block), resident, energy: EnergyModel::default() }
    }

    pub fn module(&self) -> &AttnModule {
        &self.module
    }

    pub fn block(&self) -> Option<&EncoderBlock> {
        self.block.as_ref()
    }

    /// The energy model used for power summaries in [`Self::describe`].
    pub fn energy_model(&self) -> &EnergyModel {
        &self.energy
    }
}

fn describe_module(m: &AttnModule) -> String {
    format!(
        "systolic-array simulator: D_in={} D_out={} heads={} bits[{}] ({}{}), activity-based energy model",
        m.d_in(),
        m.d_out(),
        m.heads,
        m.profile.key(),
        if m.shift { "shift-exp" } else { "exact-exp" },
        if m.wo.is_some() { ", W_O wired" } else { "" },
    )
}

/// Convert one simulator output into the uniform response shape.
pub(crate) fn response_from_output(out: AttentionOutput, elapsed: Duration) -> AttnResponse {
    AttnResponse {
        out_codes: Some(out.pv_codes),
        out_values: out.out_values,
        stages: Some(StageCodes {
            q: out.q_codes,
            k: out.k_codes,
            v: out.v_codes,
            attn_head0: out.attn_codes.into_iter().next().expect("at least one head"),
        }),
        report: Some(out.report),
        elapsed,
    }
}

/// Merge the per-item reports of a batch into one aggregate.
pub(crate) fn merge_batch_report(items: &[AttnResponse]) -> Option<AttentionReport> {
    let mut agg: Option<AttentionReport> = None;
    for item in items {
        if let Some(r) = &item.report {
            match &mut agg {
                None => agg = Some(r.clone()),
                Some(a) => a.absorb(r),
            }
        }
    }
    agg
}

/// Single-threaded simulator plan: the lowered [`AttentionSim`].
/// Trivially synchronous: `submit` executes inline, `poll` drains.
#[derive(Debug)]
pub struct SimPlan {
    sim: AttentionSim,
    desc: String,
    jobs: SyncJobs<AttnBatchResponse>,
}

impl SimPlan {
    pub fn new(module: &AttnModule) -> SimPlan {
        SimPlan { sim: module.to_sim(), desc: describe_module(module), jobs: SyncJobs::new() }
    }

    fn execute(&self, req: &AttnBatchRequest) -> Result<AttnBatchResponse> {
        let t0 = Instant::now();
        let mut items = Vec::with_capacity(req.items.len());
        for r in &req.items {
            let row_t0 = Instant::now();
            let out = self.sim.run(&r.x)?;
            items.push(response_from_output(out, row_t0.elapsed()));
        }
        Ok(AttnBatchResponse { report: merge_batch_report(&items), items, elapsed: t0.elapsed() })
    }
}

impl ExecutionPlan for SimPlan {
    fn backend_name(&self) -> &str {
        "sim"
    }

    fn describe(&self) -> String {
        self.desc.clone()
    }

    fn submit(&mut self, req: &AttnBatchRequest) -> Result<JobId> {
        let result = self.execute(req);
        Ok(self.jobs.push(result))
    }

    fn poll(&mut self, job: JobId) -> Result<JobState<AttnBatchResponse>> {
        self.jobs.poll(job, "sim plan")
    }
}

/// Whole-block simulator plan: the lowered [`BlockSim`] (pre-LN banks,
/// attention arrays, residual requantizers, FC1/GELU-LUT/FC2). Every
/// row's merged hardware rows land in the response report.
#[derive(Debug)]
pub struct SimBlockPlan {
    sim: BlockSim,
    jobs: SyncJobs<AttnBatchResponse>,
}

impl SimBlockPlan {
    pub fn new(block: &EncoderBlock) -> SimBlockPlan {
        SimBlockPlan { sim: block.to_sim(), jobs: SyncJobs::new() }
    }

    fn execute(&self, req: &AttnBatchRequest) -> Result<AttnBatchResponse> {
        let t0 = Instant::now();
        let mut items = Vec::with_capacity(req.items.len());
        for r in &req.items {
            let row_t0 = Instant::now();
            let out = self.sim.run(&r.x)?;
            items.push(AttnResponse {
                out_codes: Some(out.out_codes),
                out_values: None,
                stages: None,
                report: Some(out.report),
                elapsed: row_t0.elapsed(),
            });
        }
        Ok(AttnBatchResponse { report: merge_batch_report(&items), items, elapsed: t0.elapsed() })
    }
}

impl ExecutionPlan for SimBlockPlan {
    fn backend_name(&self) -> &str {
        "sim"
    }

    fn describe(&self) -> String {
        format!("systolic-array simulator, encoder block '{}' (D={})", self.sim.label, self.sim.d())
    }

    fn submit(&mut self, req: &AttnBatchRequest) -> Result<JobId> {
        let result = self.execute(req);
        Ok(self.jobs.push(result))
    }

    fn poll(&mut self, job: JobId) -> Result<JobState<AttnBatchResponse>> {
        self.jobs.poll(job, "sim block plan")
    }
}

impl Backend for SimBackend {
    fn name(&self) -> &str {
        "sim"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities { bit_exact_codes: true, hardware_stats: true, needs_artifacts: false }
    }

    fn describe(&self) -> String {
        match &self.block {
            Some(b) => format!("{} + {}", describe_module(&self.module), b.describe()),
            None => describe_module(&self.module),
        }
    }

    fn plan(&self, opts: &PlanOptions) -> Result<Box<dyn ExecutionPlan>> {
        match opts.scope {
            PlanScope::Attention => {
                ensure_plan_profile(&opts.profile, &self.module.profile, "sim attention module")?;
                Ok(Box::new(SimPlan::new(&self.module)))
            }
            PlanScope::Block => {
                let block = self.block.as_ref().ok_or_else(|| {
                    anyhow::anyhow!("sim backend was built without an encoder block (scope=Block)")
                })?;
                ensure_plan_profile(&opts.profile, &block.profile, "sim encoder block")?;
                Ok(Box::new(SimBlockPlan::new(block)))
            }
        }
    }

    /// Batch-of-one through the resident plan — same code path as
    /// `run_batch`, without re-lowering the module per call.
    fn run_attention(&mut self, req: &super::AttnRequest) -> Result<AttnResponse> {
        self.resident.run_one(req)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::BitProfile;
    use crate::backend::AttnRequest;

    #[test]
    fn sim_backend_surfaces_hardware_stats() {
        let module = AttnModule::synthetic(16, 8, 2, BitProfile::uniform(3), 5).unwrap();
        let x = module.random_input(6, 3).unwrap();
        let mut b = SimBackend::new(module);
        assert!(b.capabilities().hardware_stats);
        let resp = b.run_attention(&AttnRequest::new(x)).unwrap();
        let report = resp.report.expect("sim surfaces BlockStats");
        assert!(report.total_macs() > 0);
        assert!(report.total_power_w(b.energy_model()) > 0.0);
        assert!(resp.out_codes.is_some());
        // W_O wired: the simulator also emits the full fp output and
        // accounts the O-linear block.
        assert_eq!(resp.out_values.unwrap().len(), 6 * 8);
        assert!(report.blocks.iter().any(|bl| bl.name == "O linear"));
    }

    #[test]
    fn block_scope_surfaces_the_merged_block_report() {
        use crate::backend::{AttnRequest, PlanScope};
        let block = EncoderBlock::synthetic(12, 24, 2, BitProfile::uniform(3), 41).unwrap();
        let x = block.random_input(4, 2).unwrap();
        let want = block.run_reference(&x).unwrap();
        let backend = SimBackend::for_block(block);
        let opts = PlanOptions { scope: PlanScope::Block, ..PlanOptions::default() };
        let mut plan = backend.plan(&opts).unwrap();
        let resp = plan.run_one(&AttnRequest::new(x)).unwrap();
        assert_eq!(resp.out_codes.unwrap().codes.data, want.codes.data);
        let report = resp.report.expect("block sim surfaces stats");
        for row in ["FC1 linear", "GELU LUT", "residual add 2"] {
            assert!(report.blocks.iter().any(|b| b.name == row), "missing {row}");
        }
    }

    #[test]
    fn batch_report_merges_row_stats() {
        let module = AttnModule::synthetic(12, 6, 2, BitProfile::uniform(3), 9).unwrap();
        let single_macs = {
            let mut plan = SimPlan::new(&module);
            let req = AttnRequest::new(module.random_input(4, 1).unwrap());
            plan.run_batch(&AttnBatchRequest::single(req))
                .unwrap()
                .report
                .unwrap()
                .total_macs()
        };
        let mut plan = SimPlan::new(&module);
        let reqs: Vec<AttnRequest> = (0..3)
            .map(|i| AttnRequest::new(module.random_input(4, 1 + i).unwrap()))
            .collect();
        let resp = plan.run_batch(&AttnBatchRequest::new(reqs)).unwrap();
        // merged batch MACs = Σ per-row MACs = rows × single-run MACs
        assert_eq!(resp.report.unwrap().total_macs(), 3 * single_macs);
        let per_item: u64 = resp.items.iter().map(|i| i.report.as_ref().unwrap().total_macs()).sum();
        assert_eq!(per_item, 3 * single_macs);
    }
}
