//! [`SimMtBackend`] (`sim-mt`) — the sharded systolic-simulator
//! substrate: the same [`crate::sim::AttentionSim`] numerics as `sim`,
//! executed across a fixed worker-thread pool that the plan spawns once.
//!
//! Shard layout:
//!
//! * the per-request **front** stage (Q/K/V linears, LayerNorms, delay,
//!   reversing) shards across batch **rows** when the batch is at least
//!   [`super::PlanOptions::row_shard_threshold`] rows;
//! * the **head** stage (QKᵀ+softmax, attn·V) always shards across
//!   `rows × heads` work items;
//! * the W_O tail and stats merge run on the caller thread, in row
//!   order.
//!
//! Every shard is a pure function of `(module, row, head)` and results
//! are merged by index, so outputs are **bit-identical for any worker
//! count** — including the single-threaded `sim` backend, which runs
//! the exact same three stages inline. Shard [`BlockStats`] counters
//! partition the work, so the merged report's MAC/op totals equal the
//! unsharded totals exactly.
//!
//! [`BlockStats`]: crate::sim::BlockStats

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::sim::{merge_batch_report, response_from_output};
use super::{
    AttnBatchRequest, AttnBatchResponse, AttnModule, AttnResponse, Backend, Capabilities,
    ExecutionPlan, PlanOptions, PlanScope, QTensor,
};
use crate::block::EncoderBlock;
use crate::sim::attention::{AttentionSim, FrontOutput, HeadOutput};
use crate::sim::block::BlockSim;

/// The sharded simulator backend. `workers == 0` means "pick at plan
/// time": available parallelism, capped at 8.
pub struct SimMtBackend {
    module: AttnModule,
    /// The encoder block this backend plans at [`PlanScope::Block`].
    block: Option<EncoderBlock>,
    workers: usize,
    /// Lazily built resident plan so direct `run_attention` calls reuse
    /// one worker pool instead of spawning and joining a pool per call.
    resident: Option<SimMtPlan>,
}

impl SimMtBackend {
    pub fn new(module: AttnModule, workers: usize) -> SimMtBackend {
        SimMtBackend { module, block: None, workers, resident: None }
    }

    /// A backend that can plan the whole encoder block (its attention
    /// half also serves [`PlanScope::Attention`] plans).
    pub fn for_block(block: EncoderBlock, workers: usize) -> SimMtBackend {
        let module = block.attn.clone();
        SimMtBackend { module, block: Some(block), workers, resident: None }
    }

    pub fn module(&self) -> &AttnModule {
        &self.module
    }

    fn resolve_workers(&self, opts: &PlanOptions) -> usize {
        let w = if opts.workers > 0 {
            opts.workers
        } else if self.workers > 0 {
            self.workers
        } else {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
        };
        w.max(1)
    }
}

impl Backend for SimMtBackend {
    fn name(&self) -> &str {
        "sim-mt"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities { bit_exact_codes: true, hardware_stats: true, needs_artifacts: false }
    }

    fn describe(&self) -> String {
        let m = &self.module;
        format!(
            "sharded systolic simulator: D_in={} D_out={} heads={} {}-bit, workers={}",
            m.d_in(),
            m.d_out(),
            m.heads,
            m.bits,
            if self.workers > 0 { self.workers.to_string() } else { "auto".into() },
        )
    }

    fn plan(&self, opts: &PlanOptions) -> Result<Box<dyn ExecutionPlan>> {
        match opts.scope {
            PlanScope::Attention => Ok(Box::new(SimMtPlan::new(
                self.module.to_sim(),
                self.resolve_workers(opts),
                opts.row_shard_threshold,
            ))),
            PlanScope::Block => {
                let block = self.block.as_ref().ok_or_else(|| {
                    anyhow!("sim-mt backend was built without an encoder block (scope=Block)")
                })?;
                Ok(Box::new(SimMtBlockPlan::new(
                    block,
                    self.resolve_workers(opts),
                    opts.row_shard_threshold,
                )))
            }
        }
    }

    /// Batch-of-one through a resident plan (pool spawned on first use,
    /// reused afterwards).
    fn run_attention(&mut self, req: &super::AttnRequest) -> Result<super::AttnResponse> {
        if self.resident.is_none() {
            let opts = PlanOptions::default();
            self.resident = Some(SimMtPlan::new(
                self.module.to_sim(),
                self.resolve_workers(&opts),
                opts.row_shard_threshold,
            ));
        }
        self.resident.as_mut().expect("resident plan just built").run_one(req)
    }
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed pool of worker threads fed through one shared job channel.
/// Spawned once at plan time; joined on drop.
struct WorkerPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl WorkerPool {
    fn new(workers: usize) -> WorkerPool {
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..workers)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("sim-mt-{i}"))
                    .spawn(move || loop {
                        // the guard is held only while waiting for a job;
                        // jobs themselves run outside the lock
                        let job = rx.lock().expect("job queue poisoned").recv();
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // plan dropped
                        }
                    })
                    .expect("spawn sim-mt worker")
            })
            .collect();
        WorkerPool { tx: Some(tx), handles }
    }

    fn submit(&self, job: Job) -> Result<()> {
        self.tx
            .as_ref()
            .expect("pool running")
            .send(job)
            .map_err(|_| anyhow!("sim-mt worker pool is gone"))
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx.take(); // close the queue → workers exit their loop
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Collect `n` index-tagged shard results, failing deterministically on
/// the lowest-index error regardless of completion order.
fn collect_indexed<T>(rx: mpsc::Receiver<(usize, Result<T>)>, n: usize, what: &str) -> Result<Vec<T>> {
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let mut first_err: Option<(usize, anyhow::Error)> = None;
    for _ in 0..n {
        match rx.recv() {
            Ok((i, Ok(v))) => slots[i] = Some(v),
            Ok((i, Err(e))) => {
                if first_err.as_ref().map(|(j, _)| i < *j).unwrap_or(true) {
                    first_err = Some((i, e));
                }
            }
            Err(_) => return Err(anyhow!("sim-mt worker died mid-batch ({what})")),
        }
    }
    if let Some((i, e)) = first_err {
        return Err(e).with_context(|| format!("sim-mt {what} shard {i}"));
    }
    slots
        .into_iter()
        .enumerate()
        .map(|(i, s)| s.ok_or_else(|| anyhow!("{what} shard {i} produced no result")))
        .collect()
}

/// The sharded execution plan: one lowered simulator shared by a fixed
/// worker pool.
pub struct SimMtPlan {
    sim: Arc<AttentionSim>,
    pool: WorkerPool,
    workers: usize,
    row_threshold: usize,
}

impl SimMtPlan {
    pub fn new(sim: AttentionSim, workers: usize, row_threshold: usize) -> SimMtPlan {
        SimMtPlan { sim: Arc::new(sim), pool: WorkerPool::new(workers), workers, row_threshold }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Front stage over all rows — sharded by row above the threshold.
    fn run_fronts(&self, xs: &Arc<Vec<QTensor>>) -> Result<Vec<FrontOutput>> {
        let b = xs.len();
        if b < self.row_threshold || self.workers < 2 {
            return xs.iter().map(|x| self.sim.run_front(x)).collect();
        }
        let (tx, rx) = mpsc::channel();
        for i in 0..b {
            let (sim, xs, tx) = (Arc::clone(&self.sim), Arc::clone(xs), tx.clone());
            self.pool.submit(Box::new(move || {
                // catch panics so a poisoned shard surfaces as an error
                // instead of killing the worker (which would strand the
                // queued jobs' result senders and hang the collector)
                let r = catch_unwind(AssertUnwindSafe(|| sim.run_front(&xs[i])))
                    .unwrap_or_else(|_| Err(anyhow!("front shard {i} panicked")));
                let _ = tx.send((i, r));
            }))?;
        }
        drop(tx);
        collect_indexed(rx, b, "front")
    }

    /// Head stage — always sharded across `rows × heads` items.
    fn run_heads(&self, fronts: &Arc<Vec<FrontOutput>>) -> Result<Vec<Vec<HeadOutput>>> {
        let (b, heads) = (fronts.len(), self.sim.heads);
        let (tx, rx) = mpsc::channel();
        for i in 0..b {
            for h in 0..heads {
                let (sim, fronts, tx) = (Arc::clone(&self.sim), Arc::clone(fronts), tx.clone());
                self.pool.submit(Box::new(move || {
                    let r = catch_unwind(AssertUnwindSafe(|| sim.run_head(&fronts[i], h)))
                        .unwrap_or_else(|_| Err(anyhow!("head shard ({i}, {h}) panicked")));
                    let _ = tx.send((i * heads + h, r));
                }))?;
            }
        }
        drop(tx);
        let flat = collect_indexed(rx, b * heads, "head")?;
        let mut per_row: Vec<Vec<HeadOutput>> = (0..b).map(|_| Vec::with_capacity(heads)).collect();
        for (idx, out) in flat.into_iter().enumerate() {
            per_row[idx / heads].push(out);
        }
        Ok(per_row)
    }
}

impl ExecutionPlan for SimMtPlan {
    fn backend_name(&self) -> &str {
        "sim-mt"
    }

    fn describe(&self) -> String {
        format!(
            "sharded systolic simulator: D_in={} D_out={} heads={} {}-bit, {} workers (row shard ≥ {})",
            self.sim.wq.folded.codes.cols,
            self.sim.d_out(),
            self.sim.heads,
            self.sim.bits,
            self.workers,
            self.row_threshold,
        )
    }

    fn run_batch(&mut self, req: &AttnBatchRequest) -> Result<AttnBatchResponse> {
        let t0 = Instant::now();
        let b = req.items.len();
        if b == 0 {
            return Ok(AttnBatchResponse {
                items: Vec::new(),
                report: None,
                elapsed: t0.elapsed(),
            });
        }
        let xs: Arc<Vec<QTensor>> = Arc::new(req.items.iter().map(|r| r.x.clone()).collect());
        let fronts = Arc::new(self.run_fronts(&xs)?);
        let head_outs = self.run_heads(&fronts)?;
        // reclaim the fronts so assemble can move the tensors out; a
        // worker may still be dropping its Arc clone right after sending
        // its last result, in which case fall back to one clone
        let fronts = Arc::try_unwrap(fronts).unwrap_or_else(|arc| (*arc).clone());

        // merge + W_O tail on the caller thread, in row order
        let mut items = Vec::with_capacity(b);
        for (front, heads) in fronts.into_iter().zip(head_outs) {
            let out = self.sim.assemble(front, heads)?;
            items.push(response_from_output(out, t0.elapsed() / b as u32));
        }
        Ok(AttnBatchResponse { report: merge_batch_report(&items), items, elapsed: t0.elapsed() })
    }
}

/// The sharded whole-block plan: one lowered [`BlockSim`] shared by the
/// worker pool, batch **rows** as the shard unit (every shard runs the
/// full LN/attention/residual/MLP pipeline for its row). Shards are
/// pure functions of `(block, row)` merged by index, so outputs are
/// bit-identical for any worker count — including the single-threaded
/// `sim` block plan.
pub struct SimMtBlockPlan {
    sim: Arc<BlockSim>,
    pool: WorkerPool,
    workers: usize,
    row_threshold: usize,
}

impl SimMtBlockPlan {
    pub fn new(block: &EncoderBlock, workers: usize, row_threshold: usize) -> SimMtBlockPlan {
        SimMtBlockPlan {
            sim: Arc::new(block.to_sim()),
            pool: WorkerPool::new(workers),
            workers,
            row_threshold,
        }
    }
}

impl ExecutionPlan for SimMtBlockPlan {
    fn backend_name(&self) -> &str {
        "sim-mt"
    }

    fn describe(&self) -> String {
        format!(
            "sharded systolic simulator, encoder block '{}' (D={}), {} workers (row shard ≥ {})",
            self.sim.label,
            self.sim.d(),
            self.workers,
            self.row_threshold,
        )
    }

    fn run_batch(&mut self, req: &AttnBatchRequest) -> Result<AttnBatchResponse> {
        let t0 = Instant::now();
        let b = req.items.len();
        if b == 0 {
            return Ok(AttnBatchResponse { items: Vec::new(), report: None, elapsed: t0.elapsed() });
        }
        let outs = if b < self.row_threshold || self.workers < 2 {
            req.items.iter().map(|r| self.sim.run(&r.x)).collect::<Result<Vec<_>>>()?
        } else {
            let xs: Arc<Vec<QTensor>> = Arc::new(req.items.iter().map(|r| r.x.clone()).collect());
            let (tx, rx) = mpsc::channel();
            for i in 0..b {
                let (sim, xs, tx) = (Arc::clone(&self.sim), Arc::clone(&xs), tx.clone());
                self.pool.submit(Box::new(move || {
                    let r = catch_unwind(AssertUnwindSafe(|| sim.run(&xs[i])))
                        .unwrap_or_else(|_| Err(anyhow!("block shard {i} panicked")));
                    let _ = tx.send((i, r));
                }))?;
            }
            drop(tx);
            collect_indexed(rx, b, "block")?
        };
        let items: Vec<AttnResponse> = outs
            .into_iter()
            .map(|out| AttnResponse {
                out_codes: Some(out.out_codes),
                out_values: None,
                stages: None,
                report: Some(out.report),
                elapsed: t0.elapsed() / b as u32,
            })
            .collect();
        Ok(AttnBatchResponse { report: merge_batch_report(&items), items, elapsed: t0.elapsed() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{AttnRequest, SimBackend};

    fn batch(module: &AttnModule, rows: usize) -> AttnBatchRequest {
        AttnBatchRequest::new(
            (0..rows as u64)
                .map(|i| AttnRequest::new(module.random_input(6, 40 + i).unwrap()))
                .collect(),
        )
    }

    #[test]
    fn matches_single_threaded_sim_for_any_worker_count() {
        let module = AttnModule::synthetic(16, 8, 2, 3, 23).unwrap();
        let req = batch(&module, 3);
        let mut st = SimBackend::new(module.clone())
            .plan(&PlanOptions::default())
            .unwrap();
        let want = st.run_batch(&req).unwrap();
        for workers in [1usize, 2, 4] {
            let mut plan = SimMtPlan::new(module.to_sim(), workers, 2);
            let got = plan.run_batch(&req).unwrap();
            assert_eq!(got.items.len(), want.items.len());
            for (g, w) in got.items.iter().zip(&want.items) {
                assert_eq!(
                    g.out_codes.as_ref().unwrap().codes.data,
                    w.out_codes.as_ref().unwrap().codes.data,
                    "{workers} workers"
                );
                assert_eq!(g.out_values, w.out_values, "{workers} workers");
            }
            assert_eq!(
                got.report.unwrap().total_macs(),
                want.report.as_ref().unwrap().total_macs(),
                "{workers} workers: merged MAC totals"
            );
        }
    }

    #[test]
    fn shard_errors_surface_deterministically() {
        let module = AttnModule::synthetic(16, 8, 2, 3, 23).unwrap();
        let mut plan = SimMtPlan::new(module.to_sim(), 2, 2);
        // row 1 carries a wrong-spec tensor → the batch fails, naming it
        let good = AttnRequest::new(module.random_input(4, 1).unwrap());
        let bad = AttnRequest::new(
            QTensor::new(
                crate::quant::linear::IntMat::new(4, 16, vec![0; 64]),
                crate::quant::QuantSpec::signed(5, crate::quant::Step::new(0.12).unwrap()),
            )
            .unwrap(),
        );
        let err = plan
            .run_batch(&AttnBatchRequest::new(vec![good, bad]))
            .unwrap_err();
        assert!(format!("{err:#}").contains("shard 1"), "{err:#}");
    }

    #[test]
    fn empty_batch_is_ok() {
        let module = AttnModule::synthetic(12, 6, 1, 3, 2).unwrap();
        let mut plan = SimMtPlan::new(module.to_sim(), 2, 2);
        let resp = plan.run_batch(&AttnBatchRequest::default()).unwrap();
        assert!(resp.items.is_empty() && resp.report.is_none());
    }

    #[test]
    fn block_plan_is_bit_identical_across_worker_counts() {
        let block = EncoderBlock::synthetic(12, 24, 2, 3, 51).unwrap();
        let reqs: Vec<AttnRequest> = (0..4u64)
            .map(|i| AttnRequest::new(block.random_input(5, 80 + i).unwrap()))
            .collect();
        let req = AttnBatchRequest::new(reqs);
        let want: Vec<Vec<i32>> = req
            .items
            .iter()
            .map(|r| block.run_reference(&r.x).unwrap().codes.data)
            .collect();
        for workers in [1usize, 2, 4] {
            let mut plan = SimMtBlockPlan::new(&block, workers, 2);
            let got = plan.run_batch(&req).unwrap();
            assert_eq!(got.items.len(), want.len());
            for (g, w) in got.items.iter().zip(&want) {
                assert_eq!(&g.out_codes.as_ref().unwrap().codes.data, w, "{workers} workers");
            }
            assert!(got.report.unwrap().total_macs() > 0, "{workers} workers");
        }
        // empty batch through the block plan is fine too
        let mut plan = SimMtBlockPlan::new(&block, 2, 2);
        assert!(plan.run_batch(&AttnBatchRequest::default()).unwrap().items.is_empty());
    }
}
