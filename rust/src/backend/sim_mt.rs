//! [`SimMtBackend`] (`sim-mt`) — the sharded systolic-simulator
//! substrate: the same [`crate::sim::AttentionSim`] numerics as `sim`,
//! executed across a fixed worker-thread pool that the plan spawns once.
//!
//! Shard layout:
//!
//! * the per-request **front** stage (Q/K/V linears, LayerNorms, delay,
//!   reversing) shards across batch **rows** when the batch is at least
//!   [`super::PlanOptions::row_shard_threshold`] rows;
//! * the **head** stage (QKᵀ+softmax, attn·V) always shards across
//!   `rows × heads` work items;
//! * the W_O tail and stats merge run on the caller thread, in row
//!   order.
//!
//! Every shard is a pure function of `(module, row, head)` and results
//! are merged by index, so outputs are **bit-identical for any worker
//! count** — including the single-threaded `sim` backend, which runs
//! the exact same three stages inline. Shard [`BlockStats`] counters
//! partition the work, so the merged report's MAC/op totals equal the
//! unsharded totals exactly.
//!
//! ## The overlapped submit/poll pipeline
//!
//! Unlike the synchronous backends, `sim-mt` implements
//! [`ExecutionPlan::submit`] by **dispatching** shard jobs onto the
//! pool and returning while they run: each in-flight job is a small
//! state machine (front shards → head shards → assemble) advanced by
//! non-blocking [`ExecutionPlan::poll`] calls. The pool's shared queue
//! accepts the next batch's shards while the previous batch's rows are
//! still executing, which is what lets the coordinator overlap input
//! quantization and staging of batch N+1 with batch N's integer
//! matmuls. Completion order is caller-controlled (poll any job id);
//! results are still merged by index, so out-of-order polling is
//! bit-identical to the synchronous `run_batch` adapter
//! (`tests/async_pipeline.rs`). Dropping a plan with unfinished jobs
//! discards their results and joins the pool cleanly.
//!
//! [`BlockStats`]: crate::sim::BlockStats

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{self, TryRecvError};
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::sim::{merge_batch_report, response_from_output};
use super::{
    ensure_plan_profile, AttnBatchRequest, AttnBatchResponse, AttnModule, AttnResponse, Backend,
    Capabilities, ExecutionPlan, JobId, JobState, PlanOptions, PlanScope, QTensor,
};
use crate::block::EncoderBlock;
use crate::sim::attention::{AttentionSim, FrontOutput, HeadOutput};
use crate::sim::block::{BlockSim, BlockSimOutput};
use crate::util::pool::WorkerPool;

/// The sharded simulator backend. `workers == 0` means "pick at plan
/// time": available parallelism, capped at 8.
pub struct SimMtBackend {
    module: AttnModule,
    /// The encoder block this backend plans at [`PlanScope::Block`].
    block: Option<EncoderBlock>,
    workers: usize,
    /// Lazily built resident plan so direct `run_attention` calls reuse
    /// one worker pool instead of spawning and joining a pool per call.
    resident: Option<SimMtPlan>,
}

impl SimMtBackend {
    pub fn new(module: AttnModule, workers: usize) -> SimMtBackend {
        SimMtBackend { module, block: None, workers, resident: None }
    }

    /// A backend that can plan the whole encoder block (its attention
    /// half also serves [`PlanScope::Attention`] plans).
    pub fn for_block(block: EncoderBlock, workers: usize) -> SimMtBackend {
        let module = block.attn.clone();
        SimMtBackend { module, block: Some(block), workers, resident: None }
    }

    pub fn module(&self) -> &AttnModule {
        &self.module
    }

    fn resolve_workers(&self, opts: &PlanOptions) -> usize {
        let w = if opts.workers > 0 {
            opts.workers
        } else if self.workers > 0 {
            self.workers
        } else {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
        };
        w.max(1)
    }
}

impl Backend for SimMtBackend {
    fn name(&self) -> &str {
        "sim-mt"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities { bit_exact_codes: true, hardware_stats: true, needs_artifacts: false }
    }

    fn describe(&self) -> String {
        let m = &self.module;
        format!(
            "sharded systolic simulator: D_in={} D_out={} heads={} bits[{}], workers={}",
            m.d_in(),
            m.d_out(),
            m.heads,
            m.profile.key(),
            if self.workers > 0 { self.workers.to_string() } else { "auto".into() },
        )
    }

    fn plan(&self, opts: &PlanOptions) -> Result<Box<dyn ExecutionPlan>> {
        match opts.scope {
            PlanScope::Attention => {
                ensure_plan_profile(
                    &opts.profile,
                    &self.module.profile,
                    "sim-mt attention module",
                )?;
                Ok(Box::new(SimMtPlan::new(
                    self.module.to_sim(),
                    self.resolve_workers(opts),
                    opts.row_shard_threshold,
                )))
            }
            PlanScope::Block => {
                let block = self.block.as_ref().ok_or_else(|| {
                    anyhow!("sim-mt backend was built without an encoder block (scope=Block)")
                })?;
                ensure_plan_profile(&opts.profile, &block.profile, "sim-mt encoder block")?;
                Ok(Box::new(SimMtBlockPlan::new(
                    block,
                    self.resolve_workers(opts),
                    opts.row_shard_threshold,
                )))
            }
        }
    }

    /// Batch-of-one through a resident plan (pool spawned on first use,
    /// reused afterwards).
    fn run_attention(&mut self, req: &super::AttnRequest) -> Result<super::AttnResponse> {
        if self.resident.is_none() {
            let opts = PlanOptions::default();
            self.resident = Some(SimMtPlan::new(
                self.module.to_sim(),
                self.resolve_workers(&opts),
                opts.row_shard_threshold,
            ));
        }
        self.resident.as_mut().expect("resident plan just built").run_one(req)
    }
}

/// Non-blocking collector of `n` index-tagged shard results. Results
/// (successes *and* errors) are counted until all `n` arrived;
/// [`Self::finish`] then fails deterministically on the lowest-index
/// error regardless of completion order — the same contract the old
/// blocking collector had, advanced one `try_recv` drain at a time so
/// `poll` never blocks the caller.
struct ShardCollector<T> {
    rx: mpsc::Receiver<(usize, Result<T>)>,
    slots: Vec<Option<T>>,
    remaining: usize,
    first_err: Option<(usize, anyhow::Error)>,
    what: &'static str,
}

impl<T> ShardCollector<T> {
    fn new(rx: mpsc::Receiver<(usize, Result<T>)>, n: usize, what: &'static str) -> Self {
        ShardCollector {
            rx,
            slots: (0..n).map(|_| None).collect(),
            remaining: n,
            first_err: None,
            what,
        }
    }

    /// Drain whatever has completed; `Ok(true)` once every shard
    /// reported. Never blocks.
    fn drain(&mut self) -> Result<bool> {
        while self.remaining > 0 {
            match self.rx.try_recv() {
                Ok((i, Ok(v))) => {
                    self.slots[i] = Some(v);
                    self.remaining -= 1;
                }
                Ok((i, Err(e))) => {
                    if self.first_err.as_ref().map(|(j, _)| i < *j).unwrap_or(true) {
                        self.first_err = Some((i, e));
                    }
                    self.remaining -= 1;
                }
                Err(TryRecvError::Empty) => return Ok(false),
                Err(TryRecvError::Disconnected) => {
                    return Err(anyhow!("sim-mt worker died mid-batch ({})", self.what))
                }
            }
        }
        Ok(true)
    }

    /// Hand over the ordered results (call once `drain` returned true).
    fn finish(self) -> Result<Vec<T>> {
        if let Some((i, e)) = self.first_err {
            return Err(e).with_context(|| format!("sim-mt {} shard {i}", self.what));
        }
        let what = self.what;
        self.slots
            .into_iter()
            .enumerate()
            .map(|(i, s)| s.ok_or_else(|| anyhow!("{what} shard {i} produced no result")))
            .collect()
    }
}

/// One in-flight attention job's pipeline position.
enum MtStage {
    /// Front shards on the pool.
    Fronts(ShardCollector<FrontOutput>),
    /// Head shards on the pool (fronts collected).
    Heads { fronts: Arc<Vec<FrontOutput>>, collector: ShardCollector<HeadOutput> },
    /// Finished at submit time (empty batch, or an inline-front error).
    Done(Result<AttnBatchResponse>),
}

struct MtJob {
    t0: Instant,
    b: usize,
    stage: MtStage,
}

/// The sharded execution plan: one lowered simulator shared by a fixed
/// worker pool, with in-flight jobs tracked as per-job state machines.
pub struct SimMtPlan {
    sim: Arc<AttentionSim>,
    pool: WorkerPool,
    workers: usize,
    row_threshold: usize,
    next_job: u64,
    inflight: BTreeMap<u64, MtJob>,
}

impl SimMtPlan {
    pub fn new(sim: AttentionSim, workers: usize, row_threshold: usize) -> SimMtPlan {
        SimMtPlan {
            sim: Arc::new(sim),
            pool: WorkerPool::new("sim-mt", workers),
            workers,
            row_threshold,
            next_job: 0,
            inflight: BTreeMap::new(),
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Jobs submitted but not yet drained by `poll`.
    pub fn inflight(&self) -> usize {
        self.inflight.len()
    }

    fn dispatch_front_shards(&self, xs: &Arc<Vec<QTensor>>) -> Result<ShardCollector<FrontOutput>> {
        let b = xs.len();
        let (tx, rx) = mpsc::channel();
        for i in 0..b {
            let (sim, xs, tx) = (Arc::clone(&self.sim), Arc::clone(xs), tx.clone());
            self.pool.submit(Box::new(move || {
                // catch panics so a poisoned shard surfaces as an error
                // instead of killing the worker (which would strand the
                // queued jobs' result senders and hang the collector)
                let _span = crate::obs::global().span(crate::obs::StageKind::Shard);
                let r = catch_unwind(AssertUnwindSafe(|| sim.run_front(&xs[i])))
                    .unwrap_or_else(|_| Err(anyhow!("front shard {i} panicked")));
                let _ = tx.send((i, r));
            }))?;
        }
        Ok(ShardCollector::new(rx, b, "front"))
    }

    fn dispatch_head_shards(
        &self,
        fronts: &Arc<Vec<FrontOutput>>,
    ) -> Result<ShardCollector<HeadOutput>> {
        let (b, heads) = (fronts.len(), self.sim.heads);
        let (tx, rx) = mpsc::channel();
        for i in 0..b {
            for h in 0..heads {
                let (sim, fronts, tx) = (Arc::clone(&self.sim), Arc::clone(fronts), tx.clone());
                self.pool.submit(Box::new(move || {
                    let _span = crate::obs::global().span(crate::obs::StageKind::Shard);
                    let r = catch_unwind(AssertUnwindSafe(|| sim.run_head(&fronts[i], h)))
                        .unwrap_or_else(|_| Err(anyhow!("head shard ({i}, {h}) panicked")));
                    let _ = tx.send((i * heads + h, r));
                }))?;
            }
        }
        Ok(ShardCollector::new(rx, b * heads, "head"))
    }

    /// Merge + W_O tail on the caller thread, in row order.
    fn assemble_batch(
        &self,
        fronts: Arc<Vec<FrontOutput>>,
        flat_heads: Vec<HeadOutput>,
        b: usize,
        t0: Instant,
    ) -> Result<AttnBatchResponse> {
        let heads = self.sim.heads;
        let mut per_row: Vec<Vec<HeadOutput>> = (0..b).map(|_| Vec::with_capacity(heads)).collect();
        for (idx, out) in flat_heads.into_iter().enumerate() {
            per_row[idx / heads].push(out);
        }
        // reclaim the fronts so assemble can move the tensors out; a
        // worker may still be dropping its Arc clone right after sending
        // its last result, in which case fall back to one clone
        let fronts = Arc::try_unwrap(fronts).unwrap_or_else(|arc| (*arc).clone());
        let mut items = Vec::with_capacity(b);
        for (front, head_outs) in fronts.into_iter().zip(per_row) {
            let out = self.sim.assemble(front, head_outs)?;
            items.push(response_from_output(out, t0.elapsed() / b as u32));
        }
        Ok(AttnBatchResponse { report: merge_batch_report(&items), items, elapsed: t0.elapsed() })
    }
}

impl ExecutionPlan for SimMtPlan {
    fn backend_name(&self) -> &str {
        "sim-mt"
    }

    fn describe(&self) -> String {
        format!(
            "sharded systolic simulator: D_in={} D_out={} heads={} bits[{}], {} workers (row shard ≥ {})",
            self.sim.wq.folded.codes.cols,
            self.sim.d_out(),
            self.sim.heads,
            self.sim.profile.key(),
            self.workers,
            self.row_threshold,
        )
    }

    fn submit(&mut self, req: &AttnBatchRequest) -> Result<JobId> {
        let t0 = Instant::now();
        let b = req.items.len();
        let stage = if b == 0 {
            MtStage::Done(Ok(AttnBatchResponse {
                items: Vec::new(),
                report: None,
                elapsed: t0.elapsed(),
            }))
        } else {
            let xs: Arc<Vec<QTensor>> = Arc::new(req.items.iter().map(|r| r.x.clone()).collect());
            if b < self.row_threshold || self.workers < 2 {
                // small batch: fronts run inline (cheap), heads still
                // shard so the pool overlaps them with other jobs
                match xs.iter().map(|x| self.sim.run_front(x)).collect::<Result<Vec<_>>>() {
                    Ok(fronts) => {
                        let fronts = Arc::new(fronts);
                        MtStage::Heads {
                            collector: self.dispatch_head_shards(&fronts)?,
                            fronts,
                        }
                    }
                    // execution failures surface at poll, per contract
                    Err(e) => MtStage::Done(Err(e)),
                }
            } else {
                MtStage::Fronts(self.dispatch_front_shards(&xs)?)
            }
        };
        let id = JobId::from_raw(self.next_job);
        self.next_job += 1;
        self.inflight.insert(id.raw(), MtJob { t0, b, stage });
        Ok(id)
    }

    fn poll(&mut self, job: JobId) -> Result<JobState<AttnBatchResponse>> {
        let Some(mut entry) = self.inflight.remove(&job.raw()) else {
            return Err(anyhow!("sim-mt plan: unknown or already-drained {job}"));
        };
        // advance the state machine as far as completed shards allow;
        // an error return consumes the job (the entry is already out of
        // the map and gets dropped)
        loop {
            match entry.stage {
                MtStage::Fronts(mut c) => {
                    if !c.drain()? {
                        entry.stage = MtStage::Fronts(c);
                        self.inflight.insert(job.raw(), entry);
                        return Ok(JobState::Pending);
                    }
                    let fronts = Arc::new(c.finish()?);
                    entry.stage =
                        MtStage::Heads { collector: self.dispatch_head_shards(&fronts)?, fronts };
                }
                MtStage::Heads { fronts, mut collector } => {
                    if !collector.drain()? {
                        entry.stage = MtStage::Heads { fronts, collector };
                        self.inflight.insert(job.raw(), entry);
                        return Ok(JobState::Pending);
                    }
                    let flat = collector.finish()?;
                    let resp = self.assemble_batch(fronts, flat, entry.b, entry.t0)?;
                    return Ok(JobState::Done(resp));
                }
                MtStage::Done(result) => return result.map(JobState::Done),
            }
        }
    }
}

/// One in-flight block job: row shards on the pool, or finished.
enum MtBlockStage {
    Rows(ShardCollector<BlockSimOutput>),
    Done(Result<AttnBatchResponse>),
}

struct MtBlockJob {
    t0: Instant,
    stage: MtBlockStage,
}

/// The sharded whole-block plan: one lowered [`BlockSim`] shared by the
/// worker pool, batch **rows** as the shard unit (every shard runs the
/// full LN/attention/residual/MLP pipeline for its row). Shards are
/// pure functions of `(block, row)` merged by index, so outputs are
/// bit-identical for any worker count — including the single-threaded
/// `sim` block plan. Submit/poll follow the same overlapped pipeline as
/// [`SimMtPlan`]: the pool accepts the next batch's rows while earlier
/// batches are still in flight.
pub struct SimMtBlockPlan {
    sim: Arc<BlockSim>,
    pool: WorkerPool,
    workers: usize,
    row_threshold: usize,
    next_job: u64,
    inflight: BTreeMap<u64, MtBlockJob>,
}

impl SimMtBlockPlan {
    pub fn new(block: &EncoderBlock, workers: usize, row_threshold: usize) -> SimMtBlockPlan {
        SimMtBlockPlan {
            sim: Arc::new(block.to_sim()),
            pool: WorkerPool::new("sim-mt", workers),
            workers,
            row_threshold,
            next_job: 0,
            inflight: BTreeMap::new(),
        }
    }

    /// Jobs submitted but not yet drained by `poll`.
    pub fn inflight(&self) -> usize {
        self.inflight.len()
    }

    fn block_response(outs: Vec<BlockSimOutput>, t0: Instant) -> AttnBatchResponse {
        let b = outs.len().max(1);
        let items: Vec<AttnResponse> = outs
            .into_iter()
            .map(|out| AttnResponse {
                out_codes: Some(out.out_codes),
                out_values: None,
                stages: None,
                report: Some(out.report),
                elapsed: t0.elapsed() / b as u32,
            })
            .collect();
        AttnBatchResponse { report: merge_batch_report(&items), items, elapsed: t0.elapsed() }
    }
}

impl ExecutionPlan for SimMtBlockPlan {
    fn backend_name(&self) -> &str {
        "sim-mt"
    }

    fn describe(&self) -> String {
        format!(
            "sharded systolic simulator, encoder block '{}' (D={}), {} workers (row shard ≥ {})",
            self.sim.label,
            self.sim.d(),
            self.workers,
            self.row_threshold,
        )
    }

    fn submit(&mut self, req: &AttnBatchRequest) -> Result<JobId> {
        let t0 = Instant::now();
        let b = req.items.len();
        let stage = if b == 0 {
            MtBlockStage::Done(Ok(AttnBatchResponse {
                items: Vec::new(),
                report: None,
                elapsed: t0.elapsed(),
            }))
        } else if b < self.row_threshold || self.workers < 2 {
            // small batch: run inline; the result (or error) parks for poll
            let result = req
                .items
                .iter()
                .map(|r| self.sim.run(&r.x))
                .collect::<Result<Vec<_>>>()
                .map(|outs| Self::block_response(outs, t0));
            MtBlockStage::Done(result)
        } else {
            let xs: Arc<Vec<QTensor>> = Arc::new(req.items.iter().map(|r| r.x.clone()).collect());
            let (tx, rx) = mpsc::channel();
            for i in 0..b {
                let (sim, xs, tx) = (Arc::clone(&self.sim), Arc::clone(&xs), tx.clone());
                self.pool.submit(Box::new(move || {
                    let _span = crate::obs::global().span(crate::obs::StageKind::Shard);
                    let r = catch_unwind(AssertUnwindSafe(|| sim.run(&xs[i])))
                        .unwrap_or_else(|_| Err(anyhow!("block shard {i} panicked")));
                    let _ = tx.send((i, r));
                }))?;
            }
            MtBlockStage::Rows(ShardCollector::new(rx, b, "block"))
        };
        let id = JobId::from_raw(self.next_job);
        self.next_job += 1;
        self.inflight.insert(id.raw(), MtBlockJob { t0, stage });
        Ok(id)
    }

    fn poll(&mut self, job: JobId) -> Result<JobState<AttnBatchResponse>> {
        let Some(mut entry) = self.inflight.remove(&job.raw()) else {
            return Err(anyhow!("sim-mt block plan: unknown or already-drained {job}"));
        };
        match entry.stage {
            MtBlockStage::Rows(mut c) => {
                if !c.drain()? {
                    entry.stage = MtBlockStage::Rows(c);
                    self.inflight.insert(job.raw(), entry);
                    return Ok(JobState::Pending);
                }
                let outs = c.finish()?;
                Ok(JobState::Done(Self::block_response(outs, entry.t0)))
            }
            MtBlockStage::Done(result) => result.map(JobState::Done),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::BitProfile;
    use crate::backend::{AttnRequest, SimBackend};

    fn batch(module: &AttnModule, rows: usize) -> AttnBatchRequest {
        AttnBatchRequest::new(
            (0..rows as u64)
                .map(|i| AttnRequest::new(module.random_input(6, 40 + i).unwrap()))
                .collect(),
        )
    }

    #[test]
    fn matches_single_threaded_sim_for_any_worker_count() {
        let module = AttnModule::synthetic(16, 8, 2, BitProfile::uniform(3), 23).unwrap();
        let req = batch(&module, 3);
        let mut st = SimBackend::new(module.clone())
            .plan(&PlanOptions::default())
            .unwrap();
        let want = st.run_batch(&req).unwrap();
        for workers in [1usize, 2, 4] {
            let mut plan = SimMtPlan::new(module.to_sim(), workers, 2);
            let got = plan.run_batch(&req).unwrap();
            assert_eq!(got.items.len(), want.items.len());
            for (g, w) in got.items.iter().zip(&want.items) {
                assert_eq!(
                    g.out_codes.as_ref().unwrap().codes.data,
                    w.out_codes.as_ref().unwrap().codes.data,
                    "{workers} workers"
                );
                assert_eq!(g.out_values, w.out_values, "{workers} workers");
            }
            assert_eq!(
                got.report.unwrap().total_macs(),
                want.report.as_ref().unwrap().total_macs(),
                "{workers} workers: merged MAC totals"
            );
        }
    }

    #[test]
    fn shard_errors_surface_deterministically() {
        let module = AttnModule::synthetic(16, 8, 2, BitProfile::uniform(3), 23).unwrap();
        let mut plan = SimMtPlan::new(module.to_sim(), 2, 2);
        // row 1 carries a wrong-spec tensor → the batch fails, naming it
        let good = AttnRequest::new(module.random_input(4, 1).unwrap());
        let bad = AttnRequest::new(
            QTensor::new(
                crate::quant::linear::IntMat::new(4, 16, vec![0; 64]),
                crate::quant::QuantSpec::signed(5, crate::quant::Step::new(0.12).unwrap()),
            )
            .unwrap(),
        );
        let err = plan
            .run_batch(&AttnBatchRequest::new(vec![good, bad]))
            .unwrap_err();
        assert!(format!("{err:#}").contains("shard 1"), "{err:#}");
    }

    #[test]
    fn empty_batch_is_ok() {
        let module = AttnModule::synthetic(12, 6, 1, BitProfile::uniform(3), 2).unwrap();
        let mut plan = SimMtPlan::new(module.to_sim(), 2, 2);
        let resp = plan.run_batch(&AttnBatchRequest::default()).unwrap();
        assert!(resp.items.is_empty() && resp.report.is_none());
    }

    #[test]
    fn overlapped_jobs_poll_out_of_order() {
        let module = AttnModule::synthetic(16, 8, 2, BitProfile::uniform(3), 29).unwrap();
        // oracle: synchronous batches through a fresh plan
        let want: Vec<Vec<i32>> = (0..3)
            .map(|j| {
                let mut p = SimMtPlan::new(module.to_sim(), 2, 2);
                let req = batch(&module, 2 + j);
                p.run_batch(&req).unwrap().items[0].out_codes.as_ref().unwrap().codes.data.clone()
            })
            .collect();
        // three jobs in flight on ONE plan, drained in reverse order
        let mut plan = SimMtPlan::new(module.to_sim(), 2, 2);
        let ids: Vec<JobId> =
            (0..3).map(|j| plan.submit(&batch(&module, 2 + j)).unwrap()).collect();
        assert_eq!(plan.inflight(), 3);
        for (j, id) in ids.iter().enumerate().rev() {
            let resp = loop {
                match plan.poll(*id).unwrap() {
                    JobState::Done(r) => break r,
                    JobState::Pending => std::thread::yield_now(),
                }
            };
            assert_eq!(
                resp.items[0].out_codes.as_ref().unwrap().codes.data,
                want[j],
                "job {j} drained out of order"
            );
        }
        assert_eq!(plan.inflight(), 0);
        // a drained id no longer resolves
        assert!(plan.poll(ids[0]).is_err());
    }

    #[test]
    fn dropping_unfinished_jobs_neither_wedges_nor_leaks_the_pool() {
        let module = AttnModule::synthetic(16, 8, 2, BitProfile::uniform(3), 31).unwrap();
        let mut plan = SimMtPlan::new(module.to_sim(), 2, 2);
        // submit and never poll — the pool must keep serving other jobs
        let _abandoned = plan.submit(&batch(&module, 4)).unwrap();
        let req = batch(&module, 3);
        let got = plan.run_batch(&req).unwrap();
        assert_eq!(got.items.len(), 3);
        assert_eq!(plan.inflight(), 1, "abandoned job still parked");
        // dropping the plan with the job unfinished joins the pool
        // cleanly (a wedge here hangs the test harness)
        drop(plan);
    }

    #[test]
    fn block_plan_is_bit_identical_across_worker_counts() {
        let block = EncoderBlock::synthetic(12, 24, 2, BitProfile::uniform(3), 51).unwrap();
        let reqs: Vec<AttnRequest> = (0..4u64)
            .map(|i| AttnRequest::new(block.random_input(5, 80 + i).unwrap()))
            .collect();
        let req = AttnBatchRequest::new(reqs);
        let want: Vec<Vec<i32>> = req
            .items
            .iter()
            .map(|r| block.run_reference(&r.x).unwrap().codes.data)
            .collect();
        for workers in [1usize, 2, 4] {
            let mut plan = SimMtBlockPlan::new(&block, workers, 2);
            let got = plan.run_batch(&req).unwrap();
            assert_eq!(got.items.len(), want.len());
            for (g, w) in got.items.iter().zip(&want) {
                assert_eq!(&g.out_codes.as_ref().unwrap().codes.data, w, "{workers} workers");
            }
            assert!(got.report.unwrap().total_macs() > 0, "{workers} workers");
        }
        // empty batch through the block plan is fine too
        let mut plan = SimMtBlockPlan::new(&block, 2, 2);
        assert!(plan.run_batch(&AttnBatchRequest::default()).unwrap().items.is_empty());
    }

    #[test]
    fn block_plan_overlaps_submissions() {
        let block = EncoderBlock::synthetic(12, 24, 2, BitProfile::uniform(3), 53).unwrap();
        let mk_req = |seed: u64| {
            AttnBatchRequest::new(
                (0..3u64)
                    .map(|i| AttnRequest::new(block.random_input(5, seed + i).unwrap()))
                    .collect(),
            )
        };
        let want: Vec<Vec<Vec<i32>>> = [100u64, 200]
            .iter()
            .map(|&s| {
                mk_req(s)
                    .items
                    .iter()
                    .map(|r| block.run_reference(&r.x).unwrap().codes.data)
                    .collect()
            })
            .collect();
        let mut plan = SimMtBlockPlan::new(&block, 2, 2);
        let a = plan.submit(&mk_req(100)).unwrap();
        let b = plan.submit(&mk_req(200)).unwrap();
        assert_eq!(plan.inflight(), 2);
        for (id, rows) in [(b, &want[1]), (a, &want[0])] {
            let resp = loop {
                match plan.poll(id).unwrap() {
                    JobState::Done(r) => break r,
                    JobState::Pending => std::thread::yield_now(),
                }
            };
            for (g, w) in resp.items.iter().zip(rows) {
                assert_eq!(&g.out_codes.as_ref().unwrap().codes.data, w);
            }
        }
    }
}
