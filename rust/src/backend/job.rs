//! Job types for the two-phase **submit/poll** execution pipeline.
//!
//! [`super::ExecutionPlan::submit`] hands a batch to the plan and
//! returns a [`JobId`] immediately; [`super::ExecutionPlan::poll`]
//! observes the job until it is [`JobState::Done`]. Trivially
//! synchronous plans (`ref`, `sim`, `pjrt`) execute the batch inside
//! `submit` and park the finished response in a [`SyncJobs`] ledger;
//! genuinely concurrent plans (`sim-mt`) dispatch shards onto their
//! worker pool and let `poll` drain completions without blocking — so a
//! caller can stage and submit batch N+1 while batch N's shards are
//! still in flight.
//!
//! ## The job contract
//!
//! * A `JobId` is **per-plan**: ids from one plan mean nothing to
//!   another.
//! * Execution failures surface at `poll`, never at `submit` — `submit`
//!   only errors when the job cannot be accepted at all (e.g. the
//!   worker pool is gone). The coordinator therefore handles every
//!   execution error in one place.
//! * `poll` returning `Done` (or an execution error) **consumes** the
//!   job: polling the same id again — or an id the plan never issued —
//!   is an error, not `Pending`. This makes double-drain bugs loud.
//! * Dropping a plan with unfinished jobs is safe: in-flight shard
//!   results are discarded and the worker pool joins cleanly (pinned by
//!   `tests/async_pipeline.rs`).

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

/// Opaque handle to one batch submitted to an [`super::ExecutionPlan`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct JobId(u64);

impl JobId {
    /// Construct from a raw counter value (plan implementations only).
    pub fn from_raw(raw: u64) -> JobId {
        JobId(raw)
    }

    /// The raw counter value (stable within one plan's lifetime).
    pub fn raw(self) -> u64 {
        self.0
    }
}

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job#{}", self.0)
    }
}

/// What one `poll` observed about a submitted job.
#[derive(Debug)]
pub enum JobState<T> {
    /// Still executing — poll again.
    Pending,
    /// Finished; the result is handed over exactly once.
    Done(T),
}

impl<T> JobState<T> {
    pub fn is_pending(&self) -> bool {
        matches!(self, JobState::Pending)
    }

    /// The finished payload, if this observation completed the job.
    pub fn into_done(self) -> Option<T> {
        match self {
            JobState::Pending => None,
            JobState::Done(v) => Some(v),
        }
    }
}

/// Job ledger for trivially synchronous executors: `submit` runs the
/// batch inline and [`SyncJobs::push`]es the finished result; `poll`
/// hands it back (once) through [`SyncJobs::poll`]. Parking errors here
/// instead of returning them from `submit` keeps the submit/poll error
/// contract uniform across synchronous and concurrent plans.
#[derive(Debug)]
pub struct SyncJobs<T> {
    next: u64,
    done: BTreeMap<u64, Result<T>>,
}

// manual impl: a derived Default would needlessly require `T: Default`
impl<T> Default for SyncJobs<T> {
    fn default() -> Self {
        SyncJobs { next: 0, done: BTreeMap::new() }
    }
}

impl<T> SyncJobs<T> {
    pub fn new() -> SyncJobs<T> {
        SyncJobs::default()
    }

    /// Park a finished result and mint its job id.
    pub fn push(&mut self, result: Result<T>) -> JobId {
        let id = JobId(self.next);
        self.next += 1;
        self.done.insert(id.0, result);
        id
    }

    /// Mint the next job id without parking a result (concurrent
    /// executors that keep their own in-flight state).
    pub fn next_id(&mut self) -> JobId {
        let id = JobId(self.next);
        self.next += 1;
        id
    }

    /// Drain `job`: `Done` for a parked success, the parked error for a
    /// failure, and an explicit error for unknown / already-drained ids.
    pub fn poll(&mut self, job: JobId, who: &str) -> Result<JobState<T>> {
        match self.done.remove(&job.0) {
            Some(Ok(v)) => Ok(JobState::Done(v)),
            Some(Err(e)) => Err(e),
            None => Err(anyhow!("{who}: unknown or already-drained {job}")),
        }
    }

    /// Parked (submitted, not yet polled) job count.
    pub fn parked(&self) -> usize {
        self.done.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_jobs_hand_results_over_exactly_once() {
        let mut jobs: SyncJobs<u32> = SyncJobs::new();
        let a = jobs.push(Ok(7));
        let b = jobs.push(Err(anyhow!("boom")));
        assert_ne!(a, b);
        assert_eq!(jobs.parked(), 2);
        // out-of-order drain is fine
        let err = jobs.poll(b, "test").unwrap_err();
        assert!(format!("{err}").contains("boom"));
        match jobs.poll(a, "test").unwrap() {
            JobState::Done(v) => assert_eq!(v, 7),
            JobState::Pending => panic!("parked job must be done"),
        }
        // done consumes: a second poll is an error naming the job
        let err = jobs.poll(a, "test").unwrap_err();
        assert!(format!("{err}").contains("job#0"), "{err}");
    }

    #[test]
    fn job_ids_are_monotonic_and_display() {
        let mut jobs: SyncJobs<()> = SyncJobs::new();
        let a = jobs.next_id();
        let b = jobs.next_id();
        assert!(b > a);
        assert_eq!(format!("{a}"), "job#0");
        assert_eq!(JobId::from_raw(5).raw(), 5);
    }

    #[test]
    fn unknown_and_never_parked_ids_poll_as_errors_not_pending() {
        let mut jobs: SyncJobs<u32> = SyncJobs::new();
        // an id the ledger never issued at all
        let err = jobs.poll(JobId::from_raw(999), "wire").unwrap_err();
        assert!(format!("{err}").contains("job#999"), "{err}");
        assert!(format!("{err}").contains("wire"), "names the caller: {err}");
        // an id minted via next_id but never parked (the concurrent-plan
        // path) is indistinguishable from drained — an error, not Pending
        let minted = jobs.next_id();
        let err = jobs.poll(minted, "wire").unwrap_err();
        assert!(format!("{err}").contains("unknown or already-drained"), "{err}");
        assert_eq!(jobs.parked(), 0);
    }

    #[test]
    fn error_state_poll_consumes_the_job() {
        let mut jobs: SyncJobs<u32> = SyncJobs::new();
        let bad = jobs.push(Err(anyhow!("quant spec mismatch")));
        assert_eq!(jobs.parked(), 1);
        // the first poll surfaces the execution error...
        let err = jobs.poll(bad, "wire").unwrap_err();
        assert!(format!("{err}").contains("quant spec mismatch"), "{err}");
        // ...and consumes the job: the ledger is empty and a re-poll is
        // the loud unknown-id error naming the job, not the stale error
        assert_eq!(jobs.parked(), 0);
        let err = jobs.poll(bad, "wire").unwrap_err();
        assert!(format!("{err}").contains("unknown or already-drained"), "{err}");
        assert!(format!("{err}").contains("job#0"), "{err}");
    }

    #[test]
    fn job_state_accessors() {
        let p: JobState<u8> = JobState::Pending;
        assert!(p.is_pending());
        assert!(p.into_done().is_none());
        assert_eq!(JobState::Done(3u8).into_done(), Some(3));
    }
}
