"""The paper's self-attention module in its three inference dataflows.

``attention_fp32``      — plain float attention (upper bound).
``attention_qvit``      — Fig. 1(a): every operand is fake-quantized
                          (quantize→dequantize) *before* the matmuls, which
                          therefore run in floating point. This is the QAT
                          training graph and the Q-ViT baseline.
``attention_int``       — Fig. 1(b): operand-reordered. Dequantization
                          scales are delayed past the matmuls (Eq. 2), the
                          scalar Δ̄_X is cancelled by the following
                          LayerNorm, QKᵀ uses the Eq. 4 shift-softmax, and
                          every O(N³) op consumes integer codes. Consumes
                          the folded parameters built by ``integerize.py``.

``attention_int`` with ``shift=False`` must agree with ``attention_qvit``
to float-associativity tolerance — that equality *is* the paper's claim
that the reordering is lossless; the shift-softmax is the only approximant.
"""

from __future__ import annotations

import jax.numpy as jnp

from .configs import ModelConfig, QuantConfig
from .kernels import ref
from .quantizers import fake_quant, quantize_int


def _split_heads(x, heads: int):
    b, t, d = x.shape
    return x.reshape(b, t, heads, d // heads).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, t, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * d)


def _layernorm(x, p, eps=1e-6):
    return ref.layernorm(x, p["g"], p["b"], eps)


# ---------------------------------------------------------------------------


def attention_fp32(p, x, cfg: ModelConfig):
    q = x @ p["wq"]["w"].T + p["wq"]["b"]
    k = x @ p["wk"]["w"].T + p["wk"]["b"]
    v = x @ p["wv"]["w"].T + p["wv"]["b"]
    q = _layernorm(q, p["lnq"])
    k = _layernorm(k, p["lnk"])
    qh, kh, vh = (_split_heads(t, cfg.heads) for t in (q, k, v))
    scores = jnp.einsum("bhtd,bhsd->bhts", qh, kh) / jnp.sqrt(float(cfg.head_dim))
    attn = jnp.exp(scores - scores.max(-1, keepdims=True))
    attn = attn / attn.sum(-1, keepdims=True)
    out = _merge_heads(jnp.einsum("bhts,bhsd->bhtd", attn, vh))
    return out @ p["wo"]["w"].T + p["wo"]["b"]


# ---------------------------------------------------------------------------


def _fq_linear(x, lin, sx, sw, qcfg: QuantConfig):
    """Fake-quant linear, Fig. 1(a): dequantized operands, fp matmul."""
    xq = fake_quant(x, sx, qcfg.bits)
    wq = fake_quant(lin["w"], sw[:, None] if jnp.ndim(sw) else sw, qcfg.bits)
    return xq @ wq.T + lin["b"]


def attention_qvit(p, q_p, x, cfg: ModelConfig, qcfg: QuantConfig):
    """Q-ViT-style quantized-but-not-integerized attention (training graph)."""
    sx = q_p["sx"]
    q = _fq_linear(x, p["wq"], sx, q_p["sw_q"], qcfg)
    k = _fq_linear(x, p["wk"], sx, q_p["sw_k"], qcfg)
    v = _fq_linear(x, p["wv"], sx, q_p["sw_v"], qcfg)
    q = fake_quant(_layernorm(q, p["lnq"]), q_p["s_q"], qcfg.bits)
    k = fake_quant(_layernorm(k, p["lnk"]), q_p["s_k"], qcfg.bits)
    v = fake_quant(v, q_p["s_v"], qcfg.bits)
    qh, kh, vh = (_split_heads(t, cfg.heads) for t in (q, k, v))
    scores = jnp.einsum("bhtd,bhsd->bhts", qh, kh) / jnp.sqrt(float(cfg.head_dim))
    attn = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    attn = attn / jnp.sum(attn, axis=-1, keepdims=True)
    attn = fake_quant(attn, q_p["s_attn"], qcfg.attn_bits, signed=False)
    o = _merge_heads(jnp.einsum("bhts,bhsd->bhtd", attn, vh))
    o = fake_quant(o, q_p["s_o"], qcfg.bits)
    return o @ fake_quant(p["wo"]["w"], _pc(q_p["sw_o"]), qcfg.bits).T + p["wo"]["b"]


def _pc(sw):
    return sw[:, None] if jnp.ndim(sw) else sw


# ---------------------------------------------------------------------------
# Integerized path. ``ip`` is the folded parameter dict produced by
# integerize.fold_attention: integer weight codes plus pre-divided biases
# and post-scales, exactly the constants the hardware (and the Rust
# reference) holds.
# ---------------------------------------------------------------------------


def attention_int(ip, x_codes, cfg: ModelConfig, qcfg: QuantConfig, *, shift: bool = True):
    """Operand-reordered attention over integer activation codes.

    x_codes: (B, T, D) int32 codes of the block input (quantized by Δ̄_X).
    Returns the float attention output (post out-projection, pre-residual).

    Every matmul below is integer×integer→int32; the only fp work is the
    O(N²) epilogues the paper leaves in float (LN stats, softmax scale,
    per-channel post-scales) — Fig. 1(b)'s red datapath.
    """
    b, t, d = x_codes.shape
    x2 = x_codes.reshape(b * t, d)

    # Q/K linears: post-scale by diag(Δ_W) only — the scalar Δ̄_X is
    # cancelled by the following quantizing LayerNorm (Eq. 2, §IV-A).
    q_pre = (ref_int_matmul(x2, ip["wq"]["codes"]) + ip["wq"]["bias_folded"]) * ip["wq"]["w_scale"]
    k_pre = (ref_int_matmul(x2, ip["wk"]["codes"]) + ip["wk"]["bias_folded"]) * ip["wk"]["w_scale"]
    q_codes = ref.qlayernorm(q_pre, ip["lnq"]["g"], ip["lnq"]["b"], ip["s_q"], qcfg.bits)
    k_codes = ref.qlayernorm(k_pre, ip["lnk"]["g"], ip["lnk"]["b"], ip["s_k"], qcfg.bits)

    # V linear: full post-scale then requantize with Δ_V (scale absorbed
    # into the quantizer: codes = round(acc·eff + bias_eff)).
    v_acc = ref_int_matmul(x2, ip["wv"]["codes"]).astype(jnp.float32)
    v_codes = jnp.clip(
        jnp.round((v_acc + ip["wv"]["bias_folded"]) * ip["v_eff"]),
        qcfg.qmin,
        qcfg.qmax,
    )

    qh = _split_heads(q_codes.reshape(b, t, d), cfg.heads)
    kh = _split_heads(k_codes.reshape(b, t, d), cfg.heads)
    vh = _split_heads(v_codes.reshape(b, t, d), cfg.heads)

    # QKᵀ int matmul + shift-softmax + attn quantizer (Fig. 4).
    scores = jnp.einsum("bhtd,bhsd->bhts", qh.astype(jnp.int32), kh.astype(jnp.int32))
    sm = ref.shift_softmax if shift else ref.exact_softmax
    p_attn = sm(scores, ip["score_scale"])
    attn_codes = jnp.clip(jnp.round(p_attn / ip["s_attn"]), 0, qcfg.attn_qmax)

    # attn·V int matmul, scales absorbed into the Δ_O quantizer (Fig. 3).
    o_acc = jnp.einsum(
        "bhts,bhsd->bhtd", attn_codes.astype(jnp.int32), vh.astype(jnp.int32)
    ).astype(jnp.float32)
    o_codes = jnp.clip(jnp.round(o_acc * ip["o_eff"]), qcfg.qmin, qcfg.qmax)

    # Out-projection: Eq. 2 with Δ̄_X = Δ_O (no LN follows, so the full
    # post-scale Δ_O·diag(Δ_W) is applied).
    o2 = _merge_heads(o_codes).reshape(b * t, d)
    out = (ref_int_matmul(o2, ip["wo"]["codes"]) + ip["wo"]["bias_folded"]) * ip["wo"]["out_scale"]
    return out.reshape(b, t, d)


def ref_int_matmul(x_codes, w_codes):
    """X_q · W_qᵀ in int32 — the O(N³) op the whole paper is about."""
    return jnp.matmul(
        x_codes.astype(jnp.int32),
        w_codes.astype(jnp.int32).T,
        preferred_element_type=jnp.int32,
    )


def attention_int_pallas(ip, x_codes, cfg: ModelConfig, qcfg: QuantConfig, *, shift: bool = True):
    """attention_int with every O(N³) op running through the L1 Pallas kernels.

    Batch-1 (T, D) codes → (T, D) float output. Used for the flagship
    attention artifact and the kernel-composition tests; must agree with
    ``attention_int`` exactly (both round the same quantizer arithmetic).
    """
    from .kernels import (
        attn_value_pallas,
        int_linear_pallas,
        qk_shift_softmax_pallas,
        qlayernorm_pallas,
    )

    t, d = x_codes.shape
    h, dh = cfg.heads, cfg.head_dim

    # Q/K: Eq. 2 with the scalar Δ̄_X dropped (cancelled by the quantizing
    # LN): pass step_x=1 and the already-folded bias re-multiplied so the
    # kernel's internal fold reproduces b/(Δ̄_X·Δ_W).
    def ln_linear(lin, ln, step):
        pre = int_linear_pallas(
            x_codes, lin["codes"], lin["bias_folded"] * lin["w_scale"], 1.0, lin["w_scale"]
        )
        return qlayernorm_pallas(pre, ln["g"], ln["b"], float(step), qcfg.bits)

    q_codes = ln_linear(ip["wq"], ip["lnq"], ip["s_q"])
    k_codes = ln_linear(ip["wk"], ip["lnk"], ip["s_k"])

    v_fp = int_linear_pallas(
        x_codes,
        ip["wv"]["codes"],
        ip["wv"]["bias_folded"] * ip["wv"]["out_scale"],
        float(ip["sx"]),
        ip["wv"]["w_scale"],
    )
    v_codes = jnp.clip(jnp.round(v_fp / ip["s_v"]), qcfg.qmin, qcfg.qmax).astype(jnp.int32)

    outs = []
    for head in range(h):
        sl = slice(head * dh, (head + 1) * dh)
        attn = qk_shift_softmax_pallas(
            q_codes[:, sl],
            k_codes[:, sl],
            float(ip["score_scale"]),
            float(ip["s_attn"]),
            qcfg.attn_bits,
            shift=shift,
        )
        o = attn_value_pallas(
            attn,
            v_codes[:, sl],
            float(ip["s_attn"]),
            float(ip["s_v"]),
            float(ip["s_o"]),
            qcfg.bits,
        )
        outs.append(o)
    o_codes = jnp.concatenate(outs, axis=-1)
    return int_linear_pallas(
        o_codes,
        ip["wo"]["codes"],
        ip["wo"]["bias_folded"] * ip["wo"]["out_scale"],
        float(ip["s_o"]),
        ip["wo"]["w_scale"],
    )
