"""LSQ-style uniform quantizers with straight-through gradients.

The paper builds on Q-ViT [3], whose quantizers are learned-step-size
(LSQ-like) symmetric uniform quantizers. Three views of the same quantizer
are used across the stack:

  * ``quantize_int``   — the integer code  q = clip(round(x/Δ), qmin, qmax).
  * ``fake_quant``     — q·Δ, the dequantized value used during QAT and in
                         the Fig. 1(a) "qvit" inference path.
  * integer-carried    — the Fig. 1(b) path keeps ``q`` and folds Δ into a
                         post-matmul scale (see ``integerize.py``).

Gradients follow LSQ (Esser et al. 2020): STE on x inside the clip range,
and the step Δ receives  ∂q̂/∂Δ = (q - x/Δ) inside the range, qmin/qmax
outside, scaled by g = 1/sqrt(numel·qmax).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def int_range(bits: int, signed: bool = True):
    if signed:
        return -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    return 0, 2**bits - 1


def quantize_int(x, step, bits: int, signed: bool = True):
    """Integer codes. ``step`` broadcasts (scalar or per-channel on axis -1)."""
    qmin, qmax = int_range(bits, signed)
    return jnp.clip(jnp.round(x / step), qmin, qmax)


def dequantize(q, step):
    return q * step


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def fake_quant(x, step, bits: int, signed: bool = True):
    """Quantize-dequantize with LSQ gradients (QAT workhorse)."""
    return quantize_int(x, step, bits, signed) * step


def _fq_fwd(x, step, bits, signed):
    qmin, qmax = int_range(bits, signed)
    v = x / step
    q = jnp.clip(jnp.round(v), qmin, qmax)
    return q * step, (v, q, step)


def _fq_bwd(bits, signed, res, g):
    qmin, qmax = int_range(bits, signed)
    v, q, step = res
    inside = (v >= qmin) & (v <= qmax)
    gx = jnp.where(inside, g, 0.0)
    # LSQ step gradient: (q - v) inside, clip level outside.
    dstep_elem = jnp.where(inside, q - v, jnp.clip(v, qmin, qmax))
    gscale = 1.0 / jnp.sqrt(jnp.asarray(v.size, v.dtype) * max(qmax, 1))
    dstep = g * dstep_elem * gscale
    # Reduce to the (broadcast) shape of step — scalar or per-channel on
    # any axis (weights use (N,1), activations (D,)).
    sshape = jnp.shape(step)
    if len(sshape) == 0 or step.size == 1:
        dstep = jnp.sum(dstep).reshape(sshape)
    else:
        pad = (1,) * (dstep.ndim - len(sshape)) + sshape
        axes = tuple(i for i, s in enumerate(pad) if s == 1)
        dstep = jnp.sum(dstep, axis=axes, keepdims=True).reshape(sshape)
    return gx, dstep


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def init_step_from(x, bits: int, signed: bool = True, per_channel: bool = False, axis: int = 0):
    """LSQ init: Δ = 2·mean(|x|)/sqrt(qmax).

    ``per_channel`` keeps ``axis`` (default 0 — the out-channel axis of an
    (N, K) weight, the paper's Δ_W vector) and reduces everything else.
    """
    _, qmax = int_range(bits, signed)
    qmax = max(qmax, 1)
    if per_channel:
        axes = tuple(a for a in range(x.ndim) if a != axis)
        m = jnp.mean(jnp.abs(x), axis=axes)
    else:
        m = jnp.mean(jnp.abs(x))
    return jnp.maximum(2.0 * m / jnp.sqrt(jnp.asarray(float(qmax))), 1e-6)


def calibrate_step_minmax(x, bits: int, signed: bool = True):
    """Min-max calibration used for activation steps before QAT refines them."""
    qmin, qmax = int_range(bits, signed)
    if signed:
        return jnp.maximum(jnp.max(jnp.abs(x)) / max(qmax, 1), 1e-6)
    return jnp.maximum(jnp.max(x) / max(qmax, 1), 1e-6)
