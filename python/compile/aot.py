"""AOT build driver: train → integerize → export artifacts.

``python -m compile.aot --out ../artifacts`` produces everything the Rust
binary consumes (HLO text, weights, eval set, cross-language test vectors,
manifest.json). Heavy stages cache into ``<out>/checkpoints`` so re-runs
are incremental; ``make artifacts`` wraps this.

Emits HLO **text**, not ``.serialize()`` — xla_extension 0.5.1 rejects
jax≥0.5's 64-bit-id protos (see hlo.py and /opt/xla-example/README.md).

``--fast`` builds a small-config, few-step variant of everything (used by
CI-style smoke tests); the artifact layout is identical.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import attention, data as data_mod, hlo, integerize, train as train_mod, vit
from .configs import DataConfig, ModelConfig, QuantConfig, TrainConfig, TEST, TINY
from .kernels import ref
from .params import init_params, load_npz, reinit_qsteps, save_npz, tree_count
from .quantizers import quantize_int
from .tensorio import write_tensor

BITS = (2, 3, 8)


def _train_cfgs(fast: bool):
    if fast:
        return (
            TEST,
            TrainConfig(
                last_layer_steps=2,
                finetune_steps=6,
                warmup_steps=2,
                train_samples=256,
                eval_samples=128,
            ),
            DataConfig(img_size=TEST.img_size),
        )
    return (
        TINY,
        TrainConfig(
            last_layer_steps=30,
            finetune_steps=300,
            warmup_steps=20,
            train_samples=2048,
            eval_samples=1024,
        ),
        DataConfig(),
    )


def _fp32_tcfg(tcfg: TrainConfig) -> TrainConfig:
    """The fp32 'pretrain' stand-in: single phase, slightly shorter."""
    return dataclasses.replace(
        tcfg,
        last_layer_steps=0,
        finetune_steps=max(tcfg.finetune_steps - 50, tcfg.finetune_steps // 2, 4),
    )


def stage_train(out: str, fast: bool, log=print):
    """Train fp32 then QAT per bit-width; cache checkpoints + metrics."""
    cfg, tcfg, dcfg = _train_cfgs(fast)
    ckpt_dir = os.path.join(out, "checkpoints")
    os.makedirs(ckpt_dir, exist_ok=True)
    metrics_path = os.path.join(out, "metrics.json")
    metrics = {}
    if os.path.exists(metrics_path):
        with open(metrics_path) as f:
            metrics = json.load(f)

    template = init_params(jax.random.PRNGKey(tcfg.seed), cfg, QuantConfig(bits=3))

    def ckpt(name):
        return os.path.join(ckpt_dir, f"{name}.npz")

    # --- fp32 pretrain stand-in -------------------------------------------
    if not os.path.exists(ckpt("fp32")):
        log("=== training fp32 baseline ===")
        p, hist = train_mod.train_model(
            cfg, QuantConfig(bits=3), _fp32_tcfg(tcfg), dcfg, mode="fp32", log=log
        )
        save_npz(ckpt("fp32"), p)
        metrics["fp32"] = {"eval_acc": hist[-1]["eval_acc"], "history": hist}
        _dump(metrics_path, metrics)
    p_fp = load_npz(ckpt("fp32"), template)

    # --- QAT per bit-width -------------------------------------------------
    for bits in BITS:
        name = f"qat_{bits}b"
        qcfg = QuantConfig(bits=bits, attn_bits=min(bits, 4))
        if os.path.exists(ckpt(name)):
            continue
        log(f"=== QAT {bits}-bit ===")
        tmpl_b = init_params(jax.random.PRNGKey(tcfg.seed), cfg, qcfg)
        init = reinit_qsteps(p_fp, cfg, qcfg)
        # 8-bit converges quickly; spend the budget on the hard low-bit runs
        tq = tcfg if bits < 8 else dataclasses.replace(
            tcfg, finetune_steps=max(tcfg.finetune_steps // 2, 4)
        )
        p, hist = train_mod.train_model(cfg, qcfg, tq, dcfg, mode="qvit", init_from=init, log=log)
        save_npz(ckpt(name), p)
        metrics[name] = {"eval_acc": hist[-1]["eval_acc"], "history": hist}
        _dump(metrics_path, metrics)
        del tmpl_b
    return cfg, tcfg, dcfg, metrics


def stage_eval_int(out: str, cfg, tcfg, dcfg, metrics, log=print):
    """Table II body: eval qvit vs integerized (shift / exact) per bits."""
    template3 = init_params(jax.random.PRNGKey(tcfg.seed), cfg, QuantConfig(bits=3))
    eval_x, eval_y = data_mod.make_dataset(dcfg, tcfg.eval_samples, split_seed=1)
    metrics_path = os.path.join(out, "metrics.json")
    for bits in BITS:
        key = f"int_{bits}b"
        if key in metrics:
            continue
        qcfg = QuantConfig(bits=bits, attn_bits=min(bits, 4))
        p = load_npz(os.path.join(out, "checkpoints", f"qat_{bits}b.npz"), template3)
        ip = integerize.integerize(p, cfg, qcfg)
        accs = {}
        for variant, shift in (("shift", True), ("exact", False)):
            fwd = jax.jit(lambda imgs: vit.forward_int(ip, imgs, cfg, qcfg, shift=shift))
            correct = 0
            bs = 128
            for i in range(0, eval_x.shape[0], bs):
                logits = np.asarray(fwd(jnp.asarray(eval_x[i : i + bs])))
                correct += int((logits.argmax(-1) == eval_y[i : i + bs]).sum())
            accs[variant] = correct / eval_x.shape[0]
            log(f"[int/{bits}b/{variant}] eval accuracy = {accs[variant]:.4f}")
        metrics[key] = accs
        _dump(metrics_path, metrics)
    return metrics


def stage_export(out: str, cfg, tcfg, dcfg, metrics, fast: bool, log=print):
    """HLO text + weights + eval set + cross-language vectors + manifest."""
    template3 = init_params(jax.random.PRNGKey(tcfg.seed), cfg, QuantConfig(bits=3))
    executables = []
    batches = (1, 8)

    # fp32 model
    p_fp = load_npz(os.path.join(out, "checkpoints", "fp32.npz"), template3)
    for b in batches:
        name = f"model_fp32_b{b}"
        spec = jax.ShapeDtypeStruct((b, cfg.img_size, cfg.img_size, cfg.in_chans), jnp.float32)
        n = hlo.export(lambda imgs: (vit.forward_fp32(p_fp, imgs, cfg),), (spec,), _p(out, name))
        executables.append(_exe(name, b, "fp32", 32, cfg))
        log(f"exported {name} ({n} chars)")

    for bits in BITS:
        qcfg = QuantConfig(bits=bits, attn_bits=min(bits, 4))
        p = load_npz(os.path.join(out, "checkpoints", f"qat_{bits}b.npz"), template3)
        ip = integerize.integerize(p, cfg, qcfg)
        for b in batches:
            name = f"model_int_{bits}b_b{b}"
            spec = jax.ShapeDtypeStruct(
                (b, cfg.img_size, cfg.img_size, cfg.in_chans), jnp.float32
            )
            n = hlo.export(
                lambda imgs: (vit.forward_int(ip, imgs, cfg, qcfg, shift=True),),
                (spec,),
                _p(out, name),
            )
            executables.append(_exe(name, b, "integerized", bits, cfg))
            log(f"exported {name} ({n} chars)")
        # Q-ViT baseline (dequantize-then-fp-matmul) at serving batch size
        name = f"model_qvit_{bits}b_b8"
        spec = jax.ShapeDtypeStruct((8, cfg.img_size, cfg.img_size, cfg.in_chans), jnp.float32)
        n = hlo.export(
            lambda imgs: (vit.forward_qvit(p, imgs, cfg, qcfg),), (spec,), _p(out, name)
        )
        executables.append(_exe(name, 8, "qvit", bits, cfg))
        log(f"exported {name} ({n} chars)")

    # Flagship: attention module with the Pallas kernels inside, batch 1.
    qcfg3 = QuantConfig(bits=3, attn_bits=3)
    p3 = load_npz(os.path.join(out, "checkpoints", "qat_3b.npz"), template3)
    ip3 = integerize.integerize(p3, cfg, qcfg3)
    blk = ip3["blocks"][0]["attn"]
    spec = jax.ShapeDtypeStruct((cfg.tokens, cfg.dim), jnp.int32)
    name = "attn_pallas_3b_b1"
    n = hlo.export(
        lambda codes: (attention.attention_int_pallas(blk, codes, cfg, qcfg3, shift=True),),
        (spec,),
        _p(out, name),
    )
    log(f"exported {name} ({n} chars)")
    executables.append(
        dict(
            name=name,
            path=f"{name}.hlo.txt",
            batch=1,
            mode="attn_pallas",
            bits=3,
            inputs=[dict(shape=[cfg.tokens, cfg.dim], dtype="i32")],
            outputs=[dict(shape=[cfg.tokens, cfg.dim], dtype="f32")],
        )
    )

    # --- eval set -----------------------------------------------------------
    eval_x, eval_y = data_mod.make_dataset(dcfg, tcfg.eval_samples, split_seed=1)
    write_tensor(os.path.join(out, "eval_images.bin"), eval_x.astype(np.float32))
    write_tensor(os.path.join(out, "eval_labels.bin"), eval_y.astype(np.int32))

    # --- cross-language vectors (block-0 attention, 3-bit) -------------------
    _export_attn_case(out, cfg, qcfg3, p3, ip3)

    manifest = {
        "version": 1,
        "fast": fast,
        "model": dict(
            img_size=cfg.img_size,
            patch_size=cfg.patch_size,
            in_chans=cfg.in_chans,
            num_classes=cfg.num_classes,
            dim=cfg.dim,
            depth=cfg.depth,
            heads=cfg.heads,
            tokens=cfg.tokens,
            params=int(tree_count(p_fp)),
        ),
        "executables": executables,
        "evalset": {
            "images": "eval_images.bin",
            "labels": "eval_labels.bin",
            "count": int(eval_x.shape[0]),
        },
        "metrics": metrics,
        "bits": list(BITS),
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    log(f"manifest written with {len(executables)} executables")


def _export_attn_case(out: str, cfg, qcfg, params, iparams):
    """Bit-exact test vectors for the Rust quant/sim modules.

    Everything the Rust side needs to replay block-0 attention: folded
    constants, an input code matrix, and the expected integer outputs of
    every stage (computed by the jnp reference — the same oracle the
    Pallas kernels are tested against).
    """
    case_dir = os.path.join(out, "attn_case")
    os.makedirs(case_dir, exist_ok=True)
    blk = iparams["blocks"][0]["attn"]
    rng = np.random.default_rng(7)
    t, d = cfg.tokens, cfg.dim
    x_codes = rng.integers(qcfg.qmin, qcfg.qmax + 1, (t, d)).astype(np.int32)

    w = lambda name: blk[name]
    for name in ("wq", "wk", "wv", "wo"):
        write_tensor(os.path.join(case_dir, f"{name}_codes.bin"), np.asarray(w(name)["codes"], np.int32))
        write_tensor(os.path.join(case_dir, f"{name}_bias_folded.bin"), np.asarray(w(name)["bias_folded"], np.float32))
        write_tensor(os.path.join(case_dir, f"{name}_w_scale.bin"), np.asarray(w(name)["w_scale"], np.float32))
        write_tensor(os.path.join(case_dir, f"{name}_out_scale.bin"), np.asarray(w(name)["out_scale"], np.float32))
    for name in ("lnq", "lnk"):
        write_tensor(os.path.join(case_dir, f"{name}_g.bin"), np.asarray(blk[name]["g"], np.float32))
        write_tensor(os.path.join(case_dir, f"{name}_b.bin"), np.asarray(blk[name]["b"], np.float32))
    scalars = dict(
        sx=float(blk["sx"]),
        s_q=float(blk["s_q"]),
        s_k=float(blk["s_k"]),
        s_v=float(blk["s_v"]),
        s_attn=float(blk["s_attn"]),
        s_o=float(blk["s_o"]),
        score_scale=float(blk["score_scale"]),
        o_eff=float(blk["o_eff"]),
        bits=qcfg.bits,
        attn_bits=qcfg.attn_bits,
        heads=cfg.heads,
        head_dim=cfg.head_dim,
        tokens=cfg.tokens,
        dim=cfg.dim,
    )
    with open(os.path.join(case_dir, "scalars.json"), "w") as f:
        json.dump(scalars, f, indent=1)

    write_tensor(os.path.join(case_dir, "x_codes.bin"), x_codes)
    # expected stage outputs via the jnp reference path
    xj = jnp.asarray(x_codes)
    q_pre = (attention.ref_int_matmul(xj, blk["wq"]["codes"]) + blk["wq"]["bias_folded"]) * blk["wq"]["w_scale"]
    k_pre = (attention.ref_int_matmul(xj, blk["wk"]["codes"]) + blk["wk"]["bias_folded"]) * blk["wk"]["w_scale"]
    q_codes = ref.qlayernorm(q_pre, blk["lnq"]["g"], blk["lnq"]["b"], blk["s_q"], qcfg.bits)
    k_codes = ref.qlayernorm(k_pre, blk["lnk"]["g"], blk["lnk"]["b"], blk["s_k"], qcfg.bits)
    v_acc = attention.ref_int_matmul(xj, blk["wv"]["codes"]).astype(jnp.float32)
    v_codes = jnp.clip(
        jnp.round((v_acc + blk["wv"]["bias_folded"]) * blk["v_eff"]), qcfg.qmin, qcfg.qmax
    )
    write_tensor(os.path.join(case_dir, "q_codes.bin"), np.asarray(q_codes, np.int32))
    write_tensor(os.path.join(case_dir, "k_codes.bin"), np.asarray(k_codes, np.int32))
    write_tensor(os.path.join(case_dir, "v_codes.bin"), np.asarray(v_codes, np.int32))
    # per-head attention codes + final output
    out = attention.attention_int(blk, xj[None], cfg, qcfg, shift=True)
    write_tensor(os.path.join(case_dir, "out.bin"), np.asarray(out[0], np.float32))
    h0 = slice(0, cfg.head_dim)
    attn0, _ = ref.qk_shift_softmax(
        q_codes[:, h0], k_codes[:, h0], blk["score_scale"], blk["s_attn"], qcfg.attn_bits
    )
    write_tensor(os.path.join(case_dir, "attn_head0_codes.bin"), np.asarray(attn0, np.int32))


def _p(out, name):
    return os.path.join(out, f"{name}.hlo.txt")


def _exe(name, batch, mode, bits, cfg):
    return dict(
        name=name,
        path=f"{name}.hlo.txt",
        batch=batch,
        mode=mode,
        bits=bits,
        inputs=[dict(shape=[batch, cfg.img_size, cfg.img_size, cfg.in_chans], dtype="f32")],
        outputs=[dict(shape=[batch, cfg.num_classes], dtype="f32")],
    )


def _dump(path, obj):
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--fast", action="store_true", help="small config, few steps")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    t0 = time.time()
    cfg, tcfg, dcfg, metrics = stage_train(args.out, args.fast)
    metrics = stage_eval_int(args.out, cfg, tcfg, dcfg, metrics)
    stage_export(args.out, cfg, tcfg, dcfg, metrics, args.fast)
    print(f"artifacts built in {time.time()-t0:.0f}s -> {args.out}")


if __name__ == "__main__":
    main()
