"""Lower a jitted JAX function to HLO **text** for the Rust PJRT loader.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.
"""

from __future__ import annotations

import jax
from jax._src.lib import xla_client as xc


def to_hlo_text(fn, *example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is ESSENTIAL: the default elides big weight
    # constants as "{...}", which the HLO text parser silently reads back as
    # zeros — the model would run but with empty weights.
    text = comp.as_hlo_text(print_large_constants=True)
    if "constant({...})" in text:
        raise RuntimeError("HLO text still contains elided constants")
    return text


def export(fn, example_args, out_path: str) -> int:
    text = to_hlo_text(fn, *example_args)
    with open(out_path, "w") as f:
        f.write(text)
    return len(text)
