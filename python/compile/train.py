"""Two-phase QAT trainer with a hand-rolled LAMB optimizer (paper §V-A).

The paper fine-tunes DeiT-S on CIFAR-10 with LAMB (no weight decay),
base lr 5e-4, cosine annealing, in two phases: *last-layer* (head only)
then *fine-tuning* (all layers). We keep the optimizer, schedule shape and
phase structure, scaled down per DESIGN.md §3. LAMB is implemented from
scratch because optax is not in this image's package set.
"""

from __future__ import annotations

import functools
import math
import time
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import vit
from .configs import DataConfig, ModelConfig, QuantConfig, TrainConfig
from .params import init_params


# --------------------------------------------------------------------------
# LAMB (You et al. 2019): Adam moments + per-tensor trust-ratio scaling.
# --------------------------------------------------------------------------


def lamb_init(params):
    zeros = lambda p: jax.tree_util.tree_map(jnp.zeros_like, p)
    return {"m": zeros(params), "v": zeros(params), "t": jnp.zeros((), jnp.int32)}


def lamb_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-6):
    t = state["t"] + 1
    tf = t.astype(jnp.float32)

    def moments(m, v, g):
        return b1 * m + (1 - b1) * g, b2 * v + (1 - b2) * g * g

    mv = jax.tree_util.tree_map(lambda m, v, g: moments(m, v, g), state["m"], state["v"], grads)
    m_new = jax.tree_util.tree_map(lambda x: x[0], mv, is_leaf=lambda x: isinstance(x, tuple))
    v_new = jax.tree_util.tree_map(lambda x: x[1], mv, is_leaf=lambda x: isinstance(x, tuple))

    def step(p, m, v):
        mhat = m / (1 - b1**tf)
        vhat = v / (1 - b2**tf)
        u = mhat / (jnp.sqrt(vhat) + eps)  # no weight decay (paper §V-A)
        wn = jnp.linalg.norm(p)
        un = jnp.linalg.norm(u)
        trust = jnp.where((wn > 0) & (un > 0), wn / un, 1.0)
        return p - lr * trust * u

    new_params = jax.tree_util.tree_map(step, params, m_new, v_new)
    return new_params, {"m": m_new, "v": v_new, "t": t}


def cosine_lr(base_lr: float, step, total: int, warmup: int):
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * step / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, cos)


# --------------------------------------------------------------------------
# Loss / step functions.
# --------------------------------------------------------------------------


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def make_step(cfg: ModelConfig, qcfg: QuantConfig, mode: str, total: int, warmup: int, base_lr: float, trainable: Callable):
    """Build a jitted train step. ``trainable(path)`` masks the grads so the
    last-layer phase updates only the head (+ final LN)."""

    def loss_fn(params, images, labels):
        if mode == "fp32":
            logits = vit.forward_fp32(params, images, cfg)
        else:
            logits = vit.forward_qvit(params, images, cfg, qcfg)
        return cross_entropy(logits, labels), logits

    @jax.jit
    def train_step(params, opt, images, labels, step_idx):
        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, images, labels)
        grads = mask_grads(grads, trainable)
        lr = cosine_lr(base_lr, step_idx, total, warmup)
        params, opt = lamb_update(params, grads, opt, lr)
        acc = vit.accuracy(logits, labels)
        return params, opt, loss, acc

    return train_step


def mask_grads(grads, trainable: Callable):
    def mask(path, g):
        return g if trainable(path) else jnp.zeros_like(g)

    return jax.tree_util.tree_map_with_path(mask, grads)


def _path_names(path) -> Tuple:
    return tuple(
        getattr(k, "key", getattr(k, "idx", getattr(k, "name", None))) for k in path
    )


def head_only(path) -> bool:
    names = _path_names(path)
    return names[0] in ("head", "ln_f")


def all_params(path) -> bool:
    return True


# --------------------------------------------------------------------------
# Full recipe.
# --------------------------------------------------------------------------


def evaluate(params, images, labels, cfg, qcfg, mode: str, batch: int = 256) -> float:
    if mode == "fp32":
        fwd = jax.jit(lambda p, x: vit.forward_fp32(p, x, cfg))
    else:
        fwd = jax.jit(lambda p, x: vit.forward_qvit(p, x, cfg, qcfg))
    correct = 0
    for i in range(0, images.shape[0], batch):
        logits = fwd(params, images[i : i + batch])
        correct += int(np.sum(np.argmax(np.asarray(logits), -1) == labels[i : i + batch]))
    return correct / images.shape[0]


def train_model(
    cfg: ModelConfig,
    qcfg: QuantConfig,
    tcfg: TrainConfig,
    dcfg: DataConfig,
    mode: str = "qvit",
    init_from=None,
    log: Callable = print,
):
    """Run the paper's two-phase recipe; returns (params, history)."""
    train_x, train_y = data_mod.make_dataset(dcfg, tcfg.train_samples, split_seed=0)
    eval_x, eval_y = data_mod.make_dataset(dcfg, tcfg.eval_samples, split_seed=1)
    params = init_from if init_from is not None else init_params(
        jax.random.PRNGKey(tcfg.seed), cfg, qcfg
    )
    history = []
    phases = [
        ("last-layer", tcfg.last_layer_steps, head_only, 11),
        ("fine-tune", tcfg.finetune_steps, all_params, 23),
    ]
    for phase_name, steps, trainable, phase_seed in phases:
        if steps == 0:
            continue
        step_fn = make_step(cfg, qcfg, mode, steps, tcfg.warmup_steps, tcfg.base_lr, trainable)
        opt = lamb_init(params)
        t0 = time.time()
        it = data_mod.batches(train_x, train_y, tcfg.batch_size, steps, tcfg.seed + phase_seed)
        for i, (bx, by) in enumerate(it):
            params, opt, loss, acc = step_fn(params, opt, bx, by, i)
            if i % 50 == 0 or i == steps - 1:
                history.append(
                    dict(phase=phase_name, step=i, loss=float(loss), train_acc=float(acc))
                )
                log(
                    f"[{mode}/{qcfg.bits}b {phase_name}] step {i}/{steps} "
                    f"loss={float(loss):.4f} acc={float(acc):.3f} ({time.time()-t0:.0f}s)"
                )
    eval_acc = evaluate(params, eval_x, eval_y, cfg, qcfg, mode)
    log(f"[{mode}/{qcfg.bits}b] eval accuracy = {eval_acc:.4f}")
    history.append(dict(phase="eval", step=-1, eval_acc=eval_acc))
    return params, history
