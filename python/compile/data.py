"""Deterministic synthetic CIFAR-like dataset (DESIGN.md §3 substitution).

The paper fine-tunes on CIFAR-10 (50k/10k, 32×32×3, 10 classes). This
environment has no dataset access, so we generate a *learnable but
non-trivial* stand-in with the same tensor shapes: each class is a smooth
random prototype image (low-frequency Fourier mixture), and samples are
augmented prototypes — random translation, horizontal flip, amplitude
jitter and additive noise — mirroring the crop/flip augmentation DeiT
uses. Class information is spatially distributed, so the ViT must actually
attend across patches; fp32 reaches high accuracy while 2-bit QAT visibly
drops — the regime Table II probes.
"""

from __future__ import annotations

import numpy as np

from .configs import DataConfig


def _prototypes(cfg: DataConfig, rng: np.random.Generator) -> np.ndarray:
    """Smooth class prototypes: sum of random low-frequency 2-D cosines."""
    s = cfg.img_size
    yy, xx = np.meshgrid(np.arange(s), np.arange(s), indexing="ij")
    protos = np.zeros((cfg.num_classes, s, s, cfg.channels), np.float32)
    for c in range(cfg.num_classes):
        for ch in range(cfg.channels):
            img = np.zeros((s, s), np.float32)
            for _ in range(4):
                fy, fx = rng.uniform(0.5, 3.0, 2)
                py, px = rng.uniform(0, 2 * np.pi, 2)
                amp = rng.uniform(0.4, 1.0)
                img += amp * np.cos(2 * np.pi * fy * yy / s + py) * np.cos(
                    2 * np.pi * fx * xx / s + px
                )
            protos[c, :, :, ch] = img / 4.0
    return protos


def make_dataset(cfg: DataConfig, n: int, *, split_seed: int = 0):
    """Returns (images (n,s,s,C) float32 in ~[-1,1], labels (n,) int32)."""
    rng = np.random.default_rng(cfg.seed)  # prototypes shared across splits
    protos = _prototypes(cfg, rng)
    srng = np.random.default_rng(cfg.seed * 7919 + split_seed + 1)
    labels = srng.integers(0, cfg.num_classes, n).astype(np.int32)
    imgs = np.empty((n, cfg.img_size, cfg.img_size, cfg.channels), np.float32)
    for i, c in enumerate(labels):
        img = protos[c]
        dy, dx = srng.integers(-cfg.max_shift, cfg.max_shift + 1, 2)
        img = np.roll(img, (dy, dx), axis=(0, 1))
        if srng.random() < 0.5:
            img = img[:, ::-1]
        amp = srng.uniform(0.7, 1.3)
        noise = srng.normal(0.0, cfg.noise, img.shape).astype(np.float32)
        imgs[i] = amp * img + noise
    return imgs, labels


def batches(images, labels, batch_size: int, steps: int, seed: int):
    """Infinite shuffled batch stream, ``steps`` batches long."""
    rng = np.random.default_rng(seed)
    n = images.shape[0]
    for _ in range(steps):
        idx = rng.integers(0, n, batch_size)
        yield images[idx], labels[idx]
