"""Post-training integerization: fold scales per Eq. 2 (the paper's §III).

This is a pure parameter transformation — no data, no retraining. Given QAT
parameters (fp weights + learned LSQ steps) it emits the constants the
Fig. 1(b) datapath holds:

  * integer weight codes          W_q = clip(round(W/Δ_W))
  * folded biases                 b̃  = b / (Δ̄_X · Δ_W)
  * post-scales                   Δ̄_X·diag(Δ_W), or diag(Δ_W) alone where
                                  the scalar cancels into a LayerNorm
  * absorbed quantizer scales     e.g. (Δ_attn·Δ_V)/Δ_O for attn·V

The same folded constants are exported to ``artifacts/`` and loaded by the
Rust ``quant``/``model`` modules, so this file defines the cross-language
integerized-checkpoint contract.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig, QuantConfig
from .quantizers import quantize_int


def collapse_act_step(sx) -> jnp.ndarray:
    """Per-channel Δ_X → scalar Δ̄_X (the Eq. 2 approximation).

    The paper replaces diag(Δ_X) with Δ̄_X·I to make the reorder legal; we
    use the mean step (ablated against per-channel in bench A1).
    """
    sx = jnp.asarray(sx)
    return jnp.mean(sx) if sx.ndim else sx


def fold_linear(lin, sx_bar, sw, qcfg: QuantConfig):
    """Eq. 2 constants for one linear layer."""
    codes = quantize_int(lin["w"], sw[:, None] if jnp.ndim(sw) else sw, qcfg.bits).astype(
        jnp.int32
    )
    sw_vec = jnp.broadcast_to(jnp.asarray(sw), (lin["w"].shape[0],))
    return {
        "codes": codes,
        "bias_folded": lin["b"] / (sx_bar * sw_vec),
        "w_scale": sw_vec,  # diag(Δ_W): post-scale when Δ̄_X cancels in LN
        "out_scale": sx_bar * sw_vec,  # full post-scale Δ̄_X·diag(Δ_W)
    }


def fold_attention(p, q_p, cfg: ModelConfig, qcfg: QuantConfig):
    """Folded constants for one attention block (consumed by attention_int)."""
    sx = collapse_act_step(q_p["sx"])
    ip = {
        "sx": sx,
        "wq": fold_linear(p["wq"], sx, q_p["sw_q"], qcfg),
        "wk": fold_linear(p["wk"], sx, q_p["sw_k"], qcfg),
        "wv": fold_linear(p["wv"], sx, q_p["sw_v"], qcfg),
        "wo": fold_linear(p["wo"], q_p["s_o"], q_p["sw_o"], qcfg),
        "lnq": p["lnq"],
        "lnk": p["lnk"],
        "s_q": q_p["s_q"],
        "s_k": q_p["s_k"],
        "s_v": q_p["s_v"],
        "s_attn": q_p["s_attn"],
        "s_o": q_p["s_o"],
        # Δ_V quantizer with the linear's scales absorbed (codes =
        # round((acc+b̃)·v_eff)):
        "v_eff": sx * jnp.broadcast_to(jnp.asarray(q_p["sw_v"]), (cfg.dim,)) / q_p["s_v"],
        # QKᵀ softmax input scale  s = Δ_Q·Δ_K/√d  (Eq. 3):
        "score_scale": q_p["s_q"] * q_p["s_k"] / jnp.sqrt(float(cfg.head_dim)),
        # attn·V output quantizer with both input scales absorbed (Fig. 3):
        "o_eff": q_p["s_attn"] * q_p["s_v"] / q_p["s_o"],
    }
    return ip


def fold_mlp(p, q_p, qcfg: QuantConfig):
    sx1 = collapse_act_step(q_p["sx1"])
    return {
        "sx1": sx1,
        "sx2": q_p["sx2"],
        "fc1": fold_linear(p["w1"], sx1, q_p["sw1"], qcfg),
        "fc2": fold_linear(p["w2"], q_p["sx2"], q_p["sw2"], qcfg),
    }


def integerize(params, cfg: ModelConfig, qcfg: QuantConfig):
    """Whole-model folding. Non-attention/MLP parts stay fp32 (paper §III)."""
    return {
        "patch_embed": params["patch_embed"],
        "pos_embed": params["pos_embed"],
        "blocks": [
            {
                "ln1": blk["ln1"],
                "attn": fold_attention(blk["attn"], blk["q"]["attn"], cfg, qcfg),
                "ln2": blk["ln2"],
                "mlp": fold_mlp(blk["mlp"], blk["q"]["mlp"], qcfg),
            }
            for blk in params["blocks"]
        ],
        "ln_f": params["ln_f"],
        "head": params["head"],
    }


def lowbit_size_bytes(params, cfg: ModelConfig, qcfg: QuantConfig) -> int:
    """Checkpoint size with matmul weights stored at qcfg.bits (Table II)."""
    low_elems = 0
    fp_elems = 0
    import jax

    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        names = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        if "w" in names and any(n in names for n in ("wq", "wk", "wv", "wo", "w1", "w2", "mlp", "attn")):
            if leaf.ndim == 2:
                low_elems += leaf.size
                continue
        fp_elems += leaf.size
    return (low_elems * qcfg.bits + fp_elems * 32) // 8


def to_numpy_tree(tree):
    import jax

    return jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
