"""Pallas kernel: requantizing matmul  W_attn · V  (paper Fig. 3, §IV-B).

"Since this matrix multiplication result is passed onto a quantizer, it can
be performed at lower bit precision by absorbing the input scales for both
operands within the quantizer." — the kernel multiplies integer attention
codes by integer V codes (int32 accumulate) and re-quantizes in the epilogue
with the effective scale (Δ_attn·Δ_V)/Δ_out, never materialising a
dequantized matrix.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(eff_scale: float, out_bits: int):
    qmin, qmax = -(2 ** (out_bits - 1)), 2 ** (out_bits - 1) - 1

    def kernel(a_ref, v_ref, o_ref):
        acc = jax.lax.dot_general(
            a_ref[...],
            v_ref[...],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        o_ref[...] = jnp.clip(
            jnp.round(acc.astype(jnp.float32) * eff_scale), qmin, qmax
        ).astype(jnp.int32)

    return kernel


def attn_value_pallas(
    attn_q,
    v_q,
    step_attn: float,
    step_v: float,
    step_out: float,
    out_bits: int,
    *,
    block_m: int = 32,
    block_n: int = 32,
):
    """(M,N) attn codes × (N,D) V codes → (M,D) signed ``out_bits`` codes.

    Matches ``ref.attn_value`` (first return value).
    """
    m, n = attn_q.shape
    d = v_q.shape[1]
    bm, bd = min(block_m, m), min(block_n, d)
    assert m % bm == 0 and d % bd == 0, (m, d, bm, bd)
    eff = float(step_attn) * float(step_v) / float(step_out)
    kern = _make_kernel(eff, int(out_bits))
    return pl.pallas_call(
        kern,
        grid=(m // bm, d // bd),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i, j: (i, 0)),
            pl.BlockSpec((n, bd), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.int32),
        interpret=True,
    )(attn_q.astype(jnp.int32), v_q.astype(jnp.int32))
