"""Pure-jnp oracles for every Pallas kernel (the CORE correctness signal).

Each function here is the mathematically transparent statement of what the
corresponding kernel in this package must compute. pytest asserts
``assert_allclose(kernel(...), ref(...))`` under hypothesis sweeps, and the
Rust ``quant``/``sim`` modules are tested against exported cases generated
from these same functions, so this file anchors the whole stack.
"""

from __future__ import annotations

import jax.numpy as jnp

# --------------------------------------------------------------------------
# Eq. 2 — integerized linear layer.
# --------------------------------------------------------------------------


def int_linear(x_q, w_q, bias, step_x, step_w):
    """Y = [X_q W_qᵀ + b/(Δ̄_X·Δ_W)] · Δ̄_X · diag(Δ_W)   (paper Eq. 2).

    x_q: (..., K) integer codes carried in an int dtype or float-valued ints.
    w_q: (N, K) integer weight codes.  bias: (N,) float.
    step_x: scalar Δ̄_X.  step_w: (N,) per-channel Δ_W.
    Returns float32 (..., N): identical to dequantize-then-matmul.
    """
    acc = jnp.matmul(
        x_q.astype(jnp.int32), w_q.astype(jnp.int32).T, preferred_element_type=jnp.int32
    )
    folded_bias = bias / (step_x * step_w)
    return (acc.astype(jnp.float32) + folded_bias) * (step_x * step_w)


def dequant_linear(x_q, w_q, bias, step_x, step_w):
    """The Fig. 1(a) reference path: dequantize operands, then fp matmul."""
    x = x_q.astype(jnp.float32) * step_x
    w = w_q.astype(jnp.float32) * step_w[:, None]
    return jnp.matmul(x, w.T) + bias


# --------------------------------------------------------------------------
# Eq. 4 — base-2 shift exponential and the softmax built from it.
# --------------------------------------------------------------------------

LOG2E = 1.4426950408889634


def shift_exp(x):
    """exp(x) ≈ (1+r) · 2^⌊x·log2(e)⌋ with r the fractional exponent residue.

    This is the float-domain statement of the paper's ``(r+1) << ⌊·⌋``
    hardware shift (Eq. 4): 2^r is linearised to (1+r) on r∈[0,1), the
    classic Mitchell approximation (max rel. error ≈ 5.7%).
    """
    t = x * LOG2E
    fl = jnp.floor(t)
    r = t - fl
    return (1.0 + r) * jnp.exp2(fl)


def shift_softmax(scores, scale):
    """Row softmax over the last axis using shift_exp, max-subtracted."""
    z = scores.astype(jnp.float32) * scale
    z = z - jnp.max(z, axis=-1, keepdims=True)
    e = shift_exp(z)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def exact_softmax(scores, scale):
    z = scores.astype(jnp.float32) * scale
    z = z - jnp.max(z, axis=-1, keepdims=True)
    e = jnp.exp(z)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def qk_shift_softmax(q_q, k_q, scale, step_attn, attn_bits: int, shift: bool = True):
    """Fig. 4 module: int QKᵀ → (shift-)softmax → unsigned attn quantizer.

    q_q: (M, D) int codes, k_q: (N, D) int codes. ``scale`` already contains
    Δ_Q·Δ_K/√d. Returns (attn_q, scores): attn codes in [0, 2^attn_bits-1]
    and the raw int32 score matrix (exposed for cross-checking the sim).
    """
    scores = jnp.matmul(
        q_q.astype(jnp.int32), k_q.astype(jnp.int32).T, preferred_element_type=jnp.int32
    )
    p = shift_softmax(scores, scale) if shift else exact_softmax(scores, scale)
    qmax = 2**attn_bits - 1
    attn_q = jnp.clip(jnp.round(p / step_attn), 0, qmax)
    return attn_q, scores


# --------------------------------------------------------------------------
# Fig. 3 — requantizing matmul for  W_attn · V.
# --------------------------------------------------------------------------


def attn_value(attn_q, v_q, step_attn, step_v, step_out, out_bits: int):
    """Int matmul attn_q·V_q; input scales absorbed into the output quantizer.

    The hardware never multiplies by Δ_attn·Δ_V — the quantizer thresholds
    are pre-divided instead. Numerically: q_out = clip(round(acc·(Δa·Δv)/Δo)).
    Returns (out_q, acc).
    """
    acc = jnp.matmul(
        attn_q.astype(jnp.int32), v_q.astype(jnp.int32), preferred_element_type=jnp.int32
    )
    eff = (step_attn * step_v) / step_out
    qmin, qmax = -(2 ** (out_bits - 1)), 2 ** (out_bits - 1) - 1
    out_q = jnp.clip(jnp.round(acc.astype(jnp.float32) * eff), qmin, qmax)
    return out_q, acc


# --------------------------------------------------------------------------
# Eq. 5 / Fig. 5 — quantizing LayerNorm.
# --------------------------------------------------------------------------


def layernorm(x, gamma, beta, eps: float = 1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def qlayernorm(x, gamma, beta, step, bits: int, eps: float = 1e-6):
    """quantize(LN(x)) — the functional spec of the Fig. 5 comparator array."""
    y = layernorm(x, gamma, beta, eps)
    qmin, qmax = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    return jnp.clip(jnp.round(y / step), qmin, qmax)


def qlayernorm_comparator(x, gamma, beta, step, bits: int, eps: float = 1e-6):
    """The division/sqrt-free form actually wired in Fig. 5(b).

    Output level for element x is  qmin + #{k : LN(x) > s_k}, with
    boundaries s_k = (k - ½)·Δ, k = qmin+1 … qmax (e.g. -3.5Δ…2.5Δ at
    3 bits, the sequence quoted in §IV-B). The comparison
    LN(x) > s_k  ⟺  (x-μ)·γ > (s_k-β)·σ  is evaluated without σ = √(σ²):
    square both sides, compare [(x-μ)·γ]² vs σ²·(s_k-β)², and recover the
    ordering with sign logic (the Fig. 5 sgn block). Multiplying by γ on
    the lhs instead of dividing the threshold keeps the rule correct for
    any sign of γ and matches the division-free datapath.
    """
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True) + eps
    qmin, qmax = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    ks = jnp.arange(qmin + 1, qmax + 1, dtype=jnp.float32)
    s_k = (ks - 0.5) * step
    u = (x - mu) * gamma  # (..., D)
    t = s_k - beta[..., None]  # (D, K)
    u_ = u[..., None]  # (..., D, 1)
    var_ = var[..., None]  # (..., 1, 1)
    u_sq = u_ * u_
    t_sq = var_ * t * t
    gt = jnp.where(
        (u_ >= 0) & (t < 0),
        True,
        jnp.where(
            (u_ < 0) & (t >= 0),
            False,
            jnp.where(u_ >= 0, u_sq > t_sq, u_sq < t_sq),
        ),
    )
    return (qmin + jnp.sum(gt, axis=-1)).astype(jnp.float32)


def welford(x):
    """Eq. 5 incremental mean/variance (population variance, matches jnp.var).

    Implemented as the literal recurrence so the oracle exercises the same
    update order the systolic μ/σ² PE rows use.
    """
    import jax

    def body(carry, xi):
        i, mu, m2 = carry
        i = i + 1.0
        d = xi - mu
        mu = mu + d / i
        m2 = m2 + d * (xi - mu)
        return (i, mu, m2), None

    init = (
        jnp.zeros(x.shape[:-1], x.dtype),
        jnp.zeros(x.shape[:-1], x.dtype),
        jnp.zeros(x.shape[:-1], x.dtype),
    )
    (n, mu, m2), _ = jax.lax.scan(body, init, jnp.moveaxis(x, -1, 0))
    return mu, m2 / n
