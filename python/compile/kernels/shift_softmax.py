"""Pallas kernel: QKᵀ int matmul with embedded shift-softmax (Fig. 4 / Eq. 4).

The paper fuses exponentiation into the matmul array: each PE turns its MAC
result into ``(r+1) << ⌊s·log2(e)·acc⌋`` while a systolic adder row carries
the running Σexp to the row edge, where the quantizer thresholds are scaled
by the sum. The kernel mirrors that: one grid step owns a row-block of Q and
the *entire* K (the row sum is a hardware-global along the row, so the row
axis cannot be tiled without a second pass), computes the int32 score tile,
applies the Mitchell shift-exp, normalises by the row sum, and emits
attention codes quantized to ``attn_bits``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LOG2E = 1.4426950408889634


def _shift_exp(z):
    """(1+r)·2^⌊t⌋ for t = z·log2(e) — Eq. 4 in float form."""
    t = z * LOG2E
    fl = jnp.floor(t)
    return (1.0 + (t - fl)) * jnp.exp2(fl)


def _make_kernel(scale: float, step_attn: float, attn_bits: int, shift: bool):
    qmax = 2**attn_bits - 1

    def kernel(q_ref, k_ref, o_ref):
        scores = jax.lax.dot_general(
            q_ref[...],
            k_ref[...],
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        z = scores.astype(jnp.float32) * scale
        z = z - jnp.max(z, axis=-1, keepdims=True)
        e = _shift_exp(z) if shift else jnp.exp(z)
        p = e / jnp.sum(e, axis=-1, keepdims=True)
        o_ref[...] = jnp.clip(jnp.round(p / step_attn), 0, qmax).astype(jnp.int32)

    return kernel


def qk_shift_softmax_pallas(
    q_q,
    k_q,
    scale: float,
    step_attn: float,
    attn_bits: int,
    *,
    shift: bool = True,
    block_m: int = 32,
):
    """(M,D) × (N,D) int codes → (M,N) unsigned attention codes.

    ``scale`` already folds Δ_Q·Δ_K/√d; matches ``ref.qk_shift_softmax``.
    """
    m, d = q_q.shape
    n = k_q.shape[0]
    bm = min(block_m, m)
    assert m % bm == 0, (m, bm)
    kern = _make_kernel(float(scale), float(step_attn), int(attn_bits), bool(shift))
    return pl.pallas_call(
        kern,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            # K is row-global: the Σexp accumulator needs every column.
            pl.BlockSpec((n, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(q_q.astype(jnp.int32), k_q.astype(jnp.int32))


@functools.lru_cache(maxsize=None)
def flops_per_row(n: int, d: int) -> int:
    """MACs + exp/normalise ops for one attention row (perf model input)."""
    return 2 * n * d + 6 * n
