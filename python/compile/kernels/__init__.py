"""L1 — Pallas kernels for the integerized self-attention hot path.

All kernels run ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); correctness is anchored to the pure-jnp oracles in ``ref``.
"""

from .attn_value import attn_value_pallas
from .int_linear import int_linear_pallas
from .qlayernorm import qlayernorm_pallas
from .shift_softmax import qk_shift_softmax_pallas

__all__ = [
    "attn_value_pallas",
    "int_linear_pallas",
    "qlayernorm_pallas",
    "qk_shift_softmax_pallas",
]
