"""Pallas kernel: quantizing LayerNorm, division/sqrt-free (Fig. 5 / Eq. 5).

The hardware computes row statistics with the Eq. 5 incremental (Welford)
PE rows, then resolves each output level with comparators that never divide
or take a square root: LN(x) > s_k is decided as
``[(x-μ)·γ]² vs σ²·(s_k-β)²`` plus sign logic. The kernel evaluates exactly
that comparator bank — the output integer is qmin + (number of boundaries
crossed) — so the test against ``ref.qlayernorm`` (the round/clip form)
checks the paper's central hardware identity.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(step: float, bits: int, eps: float):
    qmin, qmax = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    nk = qmax - qmin  # number of boundaries

    def kernel(x_ref, g_ref, b_ref, o_ref):
        # Boundary ladder s_k = (k-½)Δ, k = qmin+1 … qmax (e.g. -3.5Δ…2.5Δ
        # at 3 bits). Built with iota so Pallas doesn't capture a constant.
        ks = jax.lax.iota(jnp.float32, nk) + float(qmin + 1)
        s_k = (ks - 0.5) * step
        x = x_ref[...]  # (bm, D)
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True) + eps
        u = (x - mu) * g_ref[...]  # (bm, D)
        tk = s_k[None, None, :] - b_ref[...].reshape(1, -1, 1)  # (1, D, K)
        u_ = u[..., None]  # (bm, D, 1)
        u_sq = u_ * u_
        t_sq = var[..., None] * tk * tk
        gt = jnp.where(
            (u_ >= 0) & (tk < 0),
            True,
            jnp.where(
                (u_ < 0) & (tk >= 0),
                False,
                jnp.where(u_ >= 0, u_sq > t_sq, u_sq < t_sq),
            ),
        )
        o_ref[...] = (qmin + jnp.sum(gt.astype(jnp.int32), axis=-1)).astype(jnp.int32)

    return kernel


def qlayernorm_pallas(x, gamma, beta, step: float, bits: int, *, block_m: int = 32, eps: float = 1e-6):
    """(M,D) float32 → (M,D) signed ``bits`` codes = quantize(LN(x)).

    Matches ``ref.qlayernorm`` everywhere off the (measure-zero) boundary
    ties; matches ``ref.qlayernorm_comparator`` exactly.
    """
    m, d = x.shape
    bm = min(block_m, m)
    assert m % bm == 0, (m, bm)
    kern = _make_kernel(float(step), int(bits), float(eps))
    return pl.pallas_call(
        kern,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.int32),
        interpret=True,
    )(x.astype(jnp.float32), gamma.reshape(1, d), beta.reshape(1, d))
