"""Pallas kernel for the integerized linear layer (paper Eq. 2 / Fig. 3).

The systolic linear array of the paper streams low-bit operand codes through
a PE grid and applies the folded bias + post-scale at the array boundary.
The TPU-shaped analogue (DESIGN.md §6): a tiled matmul whose BlockSpec
expresses the HBM→VMEM streaming schedule, int8-carried operands accumulated
in int32 (`preferred_element_type`), and the Eq. 2 epilogue fused into the
same kernel so no fp multiply touches the operands before the MAC.

Run with ``interpret=True`` — the CPU PJRT client cannot execute Mosaic
custom-calls (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, fb_ref, sc_ref, o_ref):
    """One (block_m × block_n) output tile.

    x_ref: (bm, K) int32 codes — the activation stream.
    w_ref: (bn, K) int32 codes — the stationary weight tile.
    fb_ref: (1, bn) folded bias  b/(Δ̄_X·Δ_W).
    sc_ref: (1, bn) post-scale  Δ̄_X·Δ_W  (paper: diag(Δ_W)·Δ̄_X).
    """
    acc = jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    o_ref[...] = (acc.astype(jnp.float32) + fb_ref[...]) * sc_ref[...]


def int_linear_pallas(x_q, w_q, bias, step_x, step_w, *, block_m: int = 32, block_n: int = 32):
    """Integerized linear: (M,K) codes × (N,K) codes → (M,N) float32.

    Equivalent to ``ref.int_linear`` (and hence to dequantize-then-matmul).
    ``step_x`` is the collapsed scalar Δ̄_X, ``step_w`` the per-channel Δ_W.
    """
    m, k = x_q.shape
    n = w_q.shape[0]
    bm, bn = min(block_m, m), min(block_n, n)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    scale = jnp.asarray(step_x * step_w, jnp.float32).reshape(1, n)
    folded_bias = (jnp.asarray(bias, jnp.float32) / (step_x * step_w)).reshape(1, n)
    return pl.pallas_call(
        _kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x_q.astype(jnp.int32), w_q.astype(jnp.int32), folded_bias, scale)


def vmem_bytes(m: int, k: int, n: int, block_m: int, block_n: int) -> int:
    """Estimated VMEM residency of one grid step (perf model, DESIGN.md §8)."""
    bm, bn = min(block_m, m), min(block_n, n)
    x = bm * k * 4
    w = bn * k * 4
    epi = 2 * bn * 4
    out = bm * bn * 4
    return x + w + epi + out
