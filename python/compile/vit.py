"""DeiT-style ViT with three inference dataflows (fp32 / qvit / integerized).

The encoder block follows the paper's Fig. 1 graph: pre-LN, quantized
Q/K/V linears, quantizing LayerNorm on Q and K, quantized attention
probabilities, quantized out-projection, then a quantized two-layer MLP.
Patch embedding, positional embedding, final LN and the classifier head
remain fp32 in every mode (the paper integerizes the self-attention module;
first/last layers stay high precision — §III).

Head style is global-average-pool (no CLS token) so the token count is a
power of two and systolic / Pallas tiles divide evenly (DESIGN.md §3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention
from .configs import ModelConfig, QuantConfig
from .kernels import ref
from .quantizers import fake_quant, quantize_int


def patchify(images, cfg: ModelConfig):
    """(B, H, W, C) → (B, tokens, patch_dim)."""
    b = images.shape[0]
    p = cfg.patch_size
    s = cfg.img_size // p
    x = images.reshape(b, s, p, s, p, cfg.in_chans)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, s * s, p * p * cfg.in_chans)


def _embed(params, images, cfg: ModelConfig):
    x = patchify(images, cfg)
    x = x @ params["patch_embed"]["w"].T + params["patch_embed"]["b"]
    return x + params["pos_embed"][None]


def _head(params, x):
    x = jnp.mean(x, axis=1)  # GAP over tokens
    x = ref.layernorm(x, params["ln_f"]["g"], params["ln_f"]["b"])
    return x @ params["head"]["w"].T + params["head"]["b"]


def _mlp_fp32(p, x):
    h = x @ p["w1"]["w"].T + p["w1"]["b"]
    h = jax.nn.gelu(h, approximate=False)
    return h @ p["w2"]["w"].T + p["w2"]["b"]


def _mlp_qvit(p, q_p, x, qcfg: QuantConfig):
    h = attention._fq_linear(x, p["w1"], q_p["sx1"], q_p["sw1"], qcfg)
    h = jax.nn.gelu(h, approximate=False)
    h = fake_quant(h, q_p["sx2"], qcfg.bits)
    w2 = fake_quant(p["w2"]["w"], attention._pc(q_p["sw2"]), qcfg.bits)
    return h @ w2.T + p["w2"]["b"]


def _mlp_int(ip, x_codes, qcfg: QuantConfig):
    """Integerized MLP: both matmuls consume codes; GELU stays fp (O(N²))."""
    b, t, d = x_codes.shape
    x2 = x_codes.reshape(b * t, d)
    h = (attention.ref_int_matmul(x2, ip["fc1"]["codes"]) + ip["fc1"]["bias_folded"]) * ip[
        "fc1"
    ]["out_scale"]
    h = jax.nn.gelu(h, approximate=False)
    h_codes = jnp.clip(jnp.round(h / ip["sx2"]), qcfg.qmin, qcfg.qmax)
    y = (attention.ref_int_matmul(h_codes, ip["fc2"]["codes"]) + ip["fc2"]["bias_folded"]) * ip[
        "fc2"
    ]["out_scale"]
    return y.reshape(b, t, d)


# ---------------------------------------------------------------------------


def forward_fp32(params, images, cfg: ModelConfig):
    x = _embed(params, images, cfg)
    for blk in params["blocks"]:
        h = ref.layernorm(x, blk["ln1"]["g"], blk["ln1"]["b"])
        x = x + attention.attention_fp32(blk["attn"], h, cfg)
        h = ref.layernorm(x, blk["ln2"]["g"], blk["ln2"]["b"])
        x = x + _mlp_fp32(blk["mlp"], h)
    return _head(params, x)


def forward_qvit(params, images, cfg: ModelConfig, qcfg: QuantConfig):
    """Fig. 1(a): fake-quant everywhere, fp matmuls. QAT training graph."""
    x = _embed(params, images, cfg)
    for blk in params["blocks"]:
        h = ref.layernorm(x, blk["ln1"]["g"], blk["ln1"]["b"])
        x = x + attention.attention_qvit(blk["attn"], blk["q"]["attn"], h, cfg, qcfg)
        h = ref.layernorm(x, blk["ln2"]["g"], blk["ln2"]["b"])
        x = x + _mlp_qvit(blk["mlp"], blk["q"]["mlp"], h, qcfg)
    return _head(params, x)


def forward_int(iparams, images, cfg: ModelConfig, qcfg: QuantConfig, *, shift: bool = True):
    """Fig. 1(b): operand-reordered integer dataflow (inference only).

    ``iparams`` comes from ``integerize.integerize``. With ``shift=False``
    (exact exp) this matches ``forward_qvit`` to fp tolerance — the
    reordering itself is lossless; Eq. 4 is the only approximation.
    """
    x = _embed(iparams, images, cfg)
    for blk in iparams["blocks"]:
        h = ref.layernorm(x, blk["ln1"]["g"], blk["ln1"]["b"])
        codes = quantize_int(h, blk["attn"]["sx"], qcfg.bits)
        x = x + attention.attention_int(blk["attn"], codes, cfg, qcfg, shift=shift)
        h = ref.layernorm(x, blk["ln2"]["g"], blk["ln2"]["b"])
        codes = quantize_int(h, blk["mlp"]["sx1"], qcfg.bits)
        x = x + _mlp_int(blk["mlp"], codes, qcfg)
    return _head(iparams, x)


def accuracy(logits, labels) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
