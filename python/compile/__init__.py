"""Build-time Python package: JAX model (L2), Pallas kernels (L1), AOT export.

Never imported at runtime — the Rust binary consumes only ``artifacts/``.
"""
