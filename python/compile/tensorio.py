"""Binary tensor interchange format (``IVT1``) between python and rust.

No serde/npz on the Rust side of this image, so the format is deliberately
trivial:  magic ``IVT1`` | u8 dtype | u8 ndim | u16 zero | ndim×u32 dims |
raw little-endian data.  ``rust/src/util/tensorio.rs`` implements the
mirror reader/writer; both sides are covered by round-trip tests.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"IVT1"
DTYPES = {0: np.float32, 1: np.int32, 2: np.int8, 3: np.uint8, 4: np.int64}
CODES = {np.dtype(v): k for k, v in DTYPES.items()}


def write_tensor(path, arr: np.ndarray) -> None:
    arr = np.ascontiguousarray(arr)
    code = CODES[arr.dtype]
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<BBH", code, arr.ndim, 0))
        f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
        f.write(arr.tobytes())


def read_tensor(path) -> np.ndarray:
    with open(path, "rb") as f:
        magic = f.read(4)
        assert magic == MAGIC, f"bad magic {magic!r} in {path}"
        code, ndim, _ = struct.unpack("<BBH", f.read(4))
        dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
        data = np.frombuffer(f.read(), dtype=DTYPES[code])
    return data.reshape(dims)
