"""Model / quantization / training configuration.

Plain dataclasses (no external deps) shared by the model, trainer, and AOT
exporter. The default model is a scaled-down DeiT-style ViT: the paper uses
DeiT-S (ImageNet-pretrained), which is substituted per DESIGN.md §3 with a
from-scratch trainable model of the same family. Global-average-pool head
(no CLS token) keeps the token count a power of two so low-bit systolic /
Pallas tiles divide evenly.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    img_size: int = 32
    patch_size: int = 4
    in_chans: int = 3
    num_classes: int = 10
    dim: int = 128
    depth: int = 4
    heads: int = 4
    mlp_ratio: int = 4

    @property
    def tokens(self) -> int:
        side = self.img_size // self.patch_size
        return side * side

    @property
    def head_dim(self) -> int:
        assert self.dim % self.heads == 0
        return self.dim // self.heads

    @property
    def patch_dim(self) -> int:
        return self.patch_size * self.patch_size * self.in_chans


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Quantization scheme, Q-ViT-style (LSQ learned steps).

    bits: operand width for weights and activations feeding matmuls.
    per_channel_weights: Δ_W is a per-output-channel vector (paper Eq. 1).
    per_channel_acts: if True, Δ_X per-channel — the paper's Eq. 2 collapses
      this to a single Δ̄_X to enable the reorder; we keep the flag for the
      ablation bench (A1 in DESIGN.md).
    shift_exp: use the Eq. 4 base-2 shift approximation in softmax
      (integerized path); False = exact exp (used to verify the reorder
      algebra is lossless).
    attn_bits: width of the quantized attention probabilities (Δ_ATTN).
    """

    bits: int = 3
    attn_bits: int = 3
    per_channel_weights: bool = True
    per_channel_acts: bool = False
    shift_exp: bool = True

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1

    @property
    def qmin(self) -> int:
        return -(2 ** (self.bits - 1))

    @property
    def attn_qmax(self) -> int:
        # attention probabilities are non-negative: unsigned levels
        return 2 ** self.attn_bits - 1


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Two-phase QAT recipe (paper §V-A, scaled down per DESIGN.md §3)."""

    batch_size: int = 32
    base_lr: float = 2e-3
    # paper: 300 epochs each phase with LAMB + cosine; we keep the optimizer
    # and schedule shape but shrink the step counts for the build budget.
    last_layer_steps: int = 150
    finetune_steps: int = 600
    warmup_steps: int = 30
    seed: int = 0
    train_samples: int = 4096
    eval_samples: int = 1024


@dataclasses.dataclass(frozen=True)
class DataConfig:
    """Synthetic CIFAR-like dataset (DESIGN.md §3 substitution)."""

    img_size: int = 32
    channels: int = 3
    num_classes: int = 10
    seed: int = 1234
    noise: float = 0.3
    max_shift: int = 3


TINY = ModelConfig()
# An even smaller config used by unit tests so interpret-mode Pallas stays fast.
TEST = ModelConfig(img_size=16, patch_size=4, dim=32, depth=2, heads=2)


def bit_variants() -> Tuple[int, ...]:
    """Bit-widths swept by Table II (2/3-bit ours vs 8-bit I-ViT-class)."""
    return (2, 3, 8)
