"""Parameter initialisation and pytree utilities for the ViT.

Parameters live in plain nested dicts (no flax in this image). Weight
matrices use the (out_features, in_features) = (N, K) layout throughout —
the same layout the paper's Eq. 1 writes as W_qᵀ with per-output-channel
step vector Δ_W, and the layout the Rust side consumes.

Quantizer step sizes (LSQ) are part of the trainable tree under the
``"q"`` key of each module so QAT learns them jointly with the weights.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import ModelConfig, QuantConfig
from .quantizers import init_step_from


def _linear(key, n_out: int, n_in: int):
    w = jax.random.normal(key, (n_out, n_in), jnp.float32) * (2.0 / (n_in + n_out)) ** 0.5
    return {"w": w, "b": jnp.zeros((n_out,), jnp.float32)}


def _ln(dim: int):
    return {"g": jnp.ones((dim,), jnp.float32), "b": jnp.zeros((dim,), jnp.float32)}


def _qsteps(block_params, cfg: ModelConfig, qcfg: QuantConfig):
    """Initial LSQ steps for one encoder block (refined during QAT)."""
    a = block_params["attn"]
    pc = qcfg.per_channel_weights
    act_shape = (cfg.dim,) if qcfg.per_channel_acts else ()
    one = jnp.ones(act_shape, jnp.float32) if act_shape else jnp.float32(1.0)
    return {
        "attn": {
            "sx": 0.1 * one,  # Δ_X of the LN1 output feeding Q/K/V linears
            "sw_q": init_step_from(a["wq"]["w"], qcfg.bits, per_channel=pc),
            "sw_k": init_step_from(a["wk"]["w"], qcfg.bits, per_channel=pc),
            "sw_v": init_step_from(a["wv"]["w"], qcfg.bits, per_channel=pc),
            "sw_o": init_step_from(a["wo"]["w"], qcfg.bits, per_channel=pc),
            "s_q": jnp.float32(0.5),  # post-LN Q quantizer
            "s_k": jnp.float32(0.5),
            "s_v": jnp.float32(0.1),
            "s_attn": jnp.float32(1.0 / qcfg.attn_qmax),
            "s_o": jnp.float32(0.1),  # quantizer feeding the out-projection
            "sx_o": jnp.float32(0.1),
        },
        "mlp": {
            "sx1": 0.1 * one,
            "sw1": init_step_from(block_params["mlp"]["w1"]["w"], qcfg.bits, per_channel=pc),
            "sx2": jnp.float32(0.1),
            "sw2": init_step_from(block_params["mlp"]["w2"]["w"], qcfg.bits, per_channel=pc),
        },
    }


def init_params(key, cfg: ModelConfig, qcfg: QuantConfig):
    keys = jax.random.split(key, 4 + 8 * cfg.depth)
    d, h = cfg.dim, cfg.mlp_ratio * cfg.dim
    params = {
        "patch_embed": _linear(keys[0], d, cfg.patch_dim),
        "pos_embed": jax.random.normal(keys[1], (cfg.tokens, d), jnp.float32) * 0.02,
        "blocks": [],
        "ln_f": _ln(d),
        "head": _linear(keys[2], cfg.num_classes, d),
    }
    for i in range(cfg.depth):
        ks = keys[4 + 8 * i : 4 + 8 * (i + 1)]
        blk = {
            "ln1": _ln(d),
            "attn": {
                "wq": _linear(ks[0], d, d),
                "wk": _linear(ks[1], d, d),
                "wv": _linear(ks[2], d, d),
                "wo": _linear(ks[3], d, d),
                "lnq": _ln(d),
                "lnk": _ln(d),
            },
            "ln2": _ln(d),
            "mlp": {"w1": _linear(ks[4], h, d), "w2": _linear(ks[5], d, h)},
        }
        blk["q"] = _qsteps(blk, cfg, qcfg)
        params["blocks"].append(blk)
    return params


def reinit_qsteps(params, cfg: ModelConfig, qcfg: QuantConfig):
    """Re-derive LSQ steps for a new bit-width from the current weights.

    Used when switching a pretrained fp32 checkpoint into QAT at a given
    precision (the paper initialises from the DeiT checkpoint, then trains
    the quantizers jointly).
    """
    out = dict(params)
    out["blocks"] = []
    for blk in params["blocks"]:
        b = dict(blk)
        b["q"] = _qsteps(blk, cfg, qcfg)
        out["blocks"].append(b)
    return out


def flatten_tree(tree, prefix="") -> dict:
    """Pytree → {dotted-path: np.ndarray} for npz checkpointing."""
    import numpy as np

    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(flatten_tree(v, f"{prefix}{k}."))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(flatten_tree(v, f"{prefix}{i}."))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def unflatten_into(template, flat: dict):
    """Fill a template pytree (from init_params) with flattened leaves."""
    import jax.numpy as jnp

    def walk(node, prefix=""):
        if isinstance(node, dict):
            return {k: walk(v, f"{prefix}{k}.") for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return [walk(v, f"{prefix}{i}.") for i, v in enumerate(node)]
        key = prefix[:-1]
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        return jnp.asarray(flat[key])

    return walk(template)


def save_npz(path, tree):
    import numpy as np

    np.savez(path, **flatten_tree(tree))


def load_npz(path, template):
    import numpy as np

    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    return unflatten_into(template, flat)


def tree_count(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


def tree_bytes_lowbit(params, qcfg: QuantConfig, cfg: ModelConfig) -> int:
    """Storage estimate with matmul weights at qcfg.bits (Table II 'Size')."""
    total_bits = 0
    for x in jax.tree_util.tree_leaves(params):
        total_bits += x.size * 32
    low = 0
    for blk in params["blocks"]:
        for m in ("wq", "wk", "wv", "wo"):
            low += blk["attn"][m]["w"].size
        low += blk["mlp"]["w1"]["w"].size + blk["mlp"]["w2"]["w"].size
    total_bits -= low * 32
    total_bits += low * qcfg.bits
    return total_bits // 8
