"""Tensor interchange format + HLO export invariants."""

import numpy as np
import pytest

from compile import hlo
from compile.tensorio import read_tensor, write_tensor


@pytest.mark.parametrize(
    "arr",
    [
        np.arange(12, dtype=np.float32).reshape(3, 4),
        np.arange(-8, 8, dtype=np.int32).reshape(2, 2, 4),
        np.array([1, -2, 3], dtype=np.int8),
        np.array([[250, 1], [0, 7]], dtype=np.uint8),
        np.arange(4, dtype=np.int64),
    ],
)
def test_roundtrip(tmp_path, arr):
    p = tmp_path / "t.bin"
    write_tensor(p, arr)
    back = read_tensor(p)
    assert back.dtype == arr.dtype
    np.testing.assert_array_equal(back, arr)


def test_bad_magic_rejected(tmp_path):
    p = tmp_path / "bad.bin"
    p.write_bytes(b"NOPE" + b"\x00" * 16)
    with pytest.raises(AssertionError):
        read_tensor(p)


def test_header_layout_stable(tmp_path):
    """The byte layout is a cross-language contract — pin it."""
    p = tmp_path / "t.bin"
    write_tensor(p, np.array([[1.0]], dtype=np.float32))
    raw = p.read_bytes()
    assert raw[:4] == b"IVT1"
    assert raw[4] == 0  # f32 code
    assert raw[5] == 2  # ndim
    assert raw[8:12] == (1).to_bytes(4, "little")
    assert raw[12:16] == (1).to_bytes(4, "little")
    assert raw[16:20] == np.float32(1.0).tobytes()


# ------------------------------------------------------------------ HLO ---


def test_hlo_export_includes_large_constants(tmp_path):
    """Regression for the elided-constants bug: a weight matrix closed over
    by the jitted function must appear fully in the HLO text (the text
    parser reads `{...}` back as zeros — silently destroying the model)."""
    import jax
    import jax.numpy as jnp

    w = jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)).astype(np.float32))
    text = hlo.to_hlo_text(lambda x: (x @ w,), jax.ShapeDtypeStruct((4, 64), jnp.float32))
    assert "constant({...})" not in text
    assert len(text) > 64 * 64 * 4  # the constant payload is actually there
    assert "ENTRY" in text


def test_hlo_export_writes_file(tmp_path):
    import jax
    import jax.numpy as jnp

    out = tmp_path / "f.hlo.txt"
    n = hlo.export(lambda x: (x + 1.0,), (jax.ShapeDtypeStruct((2, 2), jnp.float32),), str(out))
    assert out.exists()
    assert n == len(out.read_text())
