"""Dataset determinism and the hand-rolled LAMB optimizer."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as data_mod
from compile import train as T
from compile.configs import DataConfig


def test_dataset_deterministic():
    d = DataConfig()
    a_x, a_y = data_mod.make_dataset(d, 64, split_seed=0)
    b_x, b_y = data_mod.make_dataset(d, 64, split_seed=0)
    np.testing.assert_array_equal(a_x, b_x)
    np.testing.assert_array_equal(a_y, b_y)


def test_dataset_splits_differ_but_share_prototypes():
    d = DataConfig()
    a_x, _ = data_mod.make_dataset(d, 64, split_seed=0)
    b_x, _ = data_mod.make_dataset(d, 64, split_seed=1)
    assert not np.array_equal(a_x, b_x)


def test_dataset_shapes_and_range():
    d = DataConfig()
    x, y = data_mod.make_dataset(d, 32)
    assert x.shape == (32, d.img_size, d.img_size, d.channels)
    assert x.dtype == np.float32
    assert y.min() >= 0 and y.max() < d.num_classes
    assert np.abs(x).max() < 10  # sane scale


def test_dataset_classes_separable():
    # mean intra-class distance should be below inter-class distance
    d = DataConfig(noise=0.1, max_shift=0)
    x, y = data_mod.make_dataset(d, 200, split_seed=3)
    x = x.reshape(len(x), -1)
    intra, inter = [], []
    for i in range(0, 100):
        for j in range(i + 1, min(i + 8, 200)):
            dist = np.linalg.norm(x[i] - x[j])
            (intra if y[i] == y[j] else inter).append(dist)
    assert np.mean(intra) < np.mean(inter)


def test_batches_deterministic():
    x = np.arange(40, dtype=np.float32).reshape(10, 2, 2, 1)
    y = np.arange(10, dtype=np.int32)
    a = list(data_mod.batches(x, y, 4, 3, seed=5))
    b = list(data_mod.batches(x, y, 4, 3, seed=5))
    for (ax, ay), (bx, by) in zip(a, b):
        np.testing.assert_array_equal(ax, bx)
        np.testing.assert_array_equal(ay, by)


# ---------------------------------------------------------------- LAMB ----


def test_lamb_converges_on_quadratic():
    # minimise ||w - t||² — LAMB should get close quickly
    t = jnp.asarray(np.random.default_rng(0).normal(size=16).astype(np.float32))
    params = {"w": jnp.zeros(16)}
    opt = T.lamb_init(params)
    for i in range(200):
        grads = {"w": 2 * (params["w"] - t)}
        params, opt = T.lamb_update(params, grads, opt, lr=0.05)
    assert float(jnp.linalg.norm(params["w"] - t)) < 0.2


def test_lamb_zero_grads_no_update():
    params = {"w": jnp.ones(4)}
    opt = T.lamb_init(params)
    p2, _ = T.lamb_update(params, {"w": jnp.zeros(4)}, opt, lr=0.1)
    np.testing.assert_array_equal(np.asarray(p2["w"]), np.ones(4))


def test_cosine_schedule_shape():
    total, warm = 100, 10
    lrs = [float(T.cosine_lr(1.0, s, total, warm)) for s in range(total)]
    assert lrs[0] == 0.0
    assert abs(lrs[warm] - 1.0) < 0.12  # peak right after warmup
    assert lrs[-1] < 0.01  # annealed to ~0
    assert max(lrs) <= 1.0 + 1e-6


def test_grad_masking_head_only():
    from compile.configs import TEST, QuantConfig
    from compile.params import init_params

    params = init_params(jax.random.PRNGKey(0), TEST, QuantConfig(bits=3))
    grads = jax.tree_util.tree_map(jnp.ones_like, params)
    masked = T.mask_grads(grads, T.head_only)
    assert float(jnp.sum(masked["head"]["w"])) > 0
    assert float(jnp.sum(jnp.abs(masked["blocks"][0]["attn"]["wq"]["w"]))) == 0.0
    assert float(jnp.sum(jnp.abs(masked["patch_embed"]["w"]))) == 0.0


def test_cross_entropy_matches_manual():
    logits = jnp.asarray([[2.0, 0.0], [0.0, 2.0]])
    labels = jnp.asarray([0, 1])
    got = float(T.cross_entropy(logits, labels))
    want = -np.log(np.exp(2) / (np.exp(2) + 1))
    assert abs(got - want) < 1e-6
