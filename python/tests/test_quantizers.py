"""LSQ quantizer unit tests: ranges, STE gradients, per-channel handling."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.quantizers import (
    calibrate_step_minmax,
    dequantize,
    fake_quant,
    init_step_from,
    int_range,
    quantize_int,
)


def test_int_ranges():
    assert int_range(3) == (-4, 3)
    assert int_range(2) == (-2, 1)
    assert int_range(8) == (-128, 127)
    assert int_range(3, signed=False) == (0, 7)


def test_quantize_clips_and_rounds():
    x = jnp.array([-10.0, -0.26, 0.0, 0.26, 10.0])
    q = quantize_int(x, 0.5, 3)
    np.testing.assert_array_equal(np.asarray(q), [-4, -1, 0, 1, 3])


def test_fake_quant_is_quantize_dequantize():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    step = 0.2
    fq = fake_quant(x, step, 3)
    np.testing.assert_allclose(
        np.asarray(fq), np.asarray(dequantize(quantize_int(x, step, 3), step)), rtol=0, atol=0
    )


def test_quant_error_bounded_by_half_step():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.uniform(-1.2, 1.2, 512).astype(np.float32))
    step = 0.4
    fq = np.asarray(fake_quant(x, step, 3))
    inside = np.abs(np.asarray(x)) < 1.4  # away from clip boundary
    assert np.all(np.abs(fq - np.asarray(x))[inside] <= step / 2 + 1e-6)


def test_ste_passes_gradient_inside_range():
    x = jnp.array([0.1, 0.2, -0.3])
    g = jax.grad(lambda v: jnp.sum(fake_quant(v, 0.5, 3)))(x)
    np.testing.assert_array_equal(np.asarray(g), [1.0, 1.0, 1.0])


def test_ste_blocks_gradient_outside_range():
    x = jnp.array([100.0, -100.0])
    g = jax.grad(lambda v: jnp.sum(fake_quant(v, 0.5, 3)))(x)
    np.testing.assert_array_equal(np.asarray(g), [0.0, 0.0])


def test_step_gradient_shape_scalar_and_per_channel():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))
    s_scalar = jnp.float32(0.3)
    g1 = jax.grad(lambda s: jnp.sum(fake_quant(x, s, 3)))(s_scalar)
    assert np.ndim(g1) == 0 and np.isfinite(g1)
    # per-out-channel for (N, K) weights: step shape (N, 1)
    s_pc = jnp.full((8, 1), 0.3, jnp.float32)
    g2 = jax.grad(lambda s: jnp.sum(fake_quant(x, s, 3)))(s_pc)
    assert g2.shape == (8, 1)
    assert np.all(np.isfinite(np.asarray(g2)))


def test_step_gradient_sign_sane():
    # If the step is far too large, LSQ should push it down (positive
    # gradient on loss = sum of |fq - x| ... use MSE): check finite & nonzero.
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=256).astype(np.float32))

    def loss(s):
        return jnp.mean((fake_quant(x, s, 3) - x) ** 2)

    g_small = jax.grad(loss)(jnp.float32(1e-3))
    g_large = jax.grad(loss)(jnp.float32(10.0))
    assert g_small < 0  # too-small step should grow
    assert g_large > 0  # too-large step should shrink


def test_init_step_from_per_channel_axis0():
    w = jnp.stack([jnp.ones(16), 10 * jnp.ones(16)])  # (2, 16)
    s = init_step_from(w, 3, per_channel=True)
    assert s.shape == (2,)
    assert float(s[1]) > 5 * float(s[0])


def test_calibrate_minmax_covers_range():
    x = jnp.array([-3.0, 0.5, 2.0])
    s = calibrate_step_minmax(x, 3)
    assert np.isclose(float(s) * 3, 3.0)


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
def test_roundtrip_codes_within_range(bits):
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=128).astype(np.float32))
    q = np.asarray(quantize_int(x, 0.1, bits))
    lo, hi = int_range(bits)
    assert q.min() >= lo and q.max() <= hi
