"""Eq. 2 folding tests (python side of the cross-language contract)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import integerize
from compile.configs import TEST, QuantConfig
from compile.kernels import ref
from compile.params import init_params

CFG = TEST
QCFG = QuantConfig(bits=3)


def test_collapse_act_step():
    assert float(integerize.collapse_act_step(jnp.asarray([1.0, 2.0, 3.0]))) == 2.0
    assert float(integerize.collapse_act_step(jnp.float32(0.5))) == 0.5


def test_fold_linear_constants():
    rng = np.random.default_rng(0)
    lin = {
        "w": jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32) * 0.1),
        "b": jnp.asarray(rng.normal(size=8).astype(np.float32)),
    }
    sw = jnp.asarray((0.02 + rng.random(8) * 0.1).astype(np.float32))
    f = integerize.fold_linear(lin, 0.1, sw, QCFG)
    assert f["codes"].shape == (8, 16)
    assert f["codes"].dtype == jnp.int32
    assert int(jnp.max(jnp.abs(f["codes"]))) <= 4
    np.testing.assert_allclose(
        np.asarray(f["out_scale"]), 0.1 * np.asarray(sw), rtol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(f["bias_folded"]) * np.asarray(f["out_scale"]),
        np.asarray(lin["b"]),
        rtol=1e-5,
    )


def test_folded_forward_equals_fake_quant_linear():
    rng = np.random.default_rng(1)
    lin = {
        "w": jnp.asarray(rng.normal(size=(12, 24)).astype(np.float32) * 0.1),
        "b": jnp.asarray(rng.normal(size=12).astype(np.float32)),
    }
    sw = jnp.asarray((0.02 + rng.random(12) * 0.1).astype(np.float32))
    sx = 0.08
    f = integerize.fold_linear(lin, sx, sw, QCFG)
    x_codes = jnp.asarray(rng.integers(-4, 4, (5, 24)).astype(np.int32))
    got = ref.int_linear(x_codes, f["codes"], lin["b"], sx, sw)
    want = ref.dequant_linear(x_codes, f["codes"], lin["b"], sx, sw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_integerize_whole_model_structure():
    params = init_params(jax.random.PRNGKey(0), CFG, QCFG)
    ip = integerize.integerize(params, CFG, QCFG)
    assert len(ip["blocks"]) == CFG.depth
    blk = ip["blocks"][0]["attn"]
    for k in ("wq", "wk", "wv", "wo"):
        assert blk[k]["codes"].shape == (CFG.dim, CFG.dim)
    assert blk["score_scale"] > 0
    assert float(blk["o_eff"]) > 0
    # fp parts passed through untouched
    np.testing.assert_array_equal(
        np.asarray(ip["pos_embed"]), np.asarray(params["pos_embed"])
    )


def test_lowbit_size_accounting():
    params = init_params(jax.random.PRNGKey(0), CFG, QCFG)
    s3 = integerize.lowbit_size_bytes(params, CFG, QuantConfig(bits=3))
    s8 = integerize.lowbit_size_bytes(params, CFG, QuantConfig(bits=8))
    s2 = integerize.lowbit_size_bytes(params, CFG, QuantConfig(bits=2))
    assert s2 < s3 < s8  # Table II "Size" ordering


def test_v_eff_absorbs_scales():
    params = init_params(jax.random.PRNGKey(0), CFG, QCFG)
    ip = integerize.integerize(params, CFG, QCFG)
    blk = ip["blocks"][0]["attn"]
    q = params["blocks"][0]["q"]["attn"]
    want = float(integerize.collapse_act_step(q["sx"])) * np.asarray(
        jnp.broadcast_to(q["sw_v"], (CFG.dim,))
    ) / float(q["s_v"])
    np.testing.assert_allclose(np.asarray(blk["v_eff"]), want, rtol=1e-6)
