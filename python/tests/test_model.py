"""Model-level tests: patchify, shapes, determinism, parameter counting."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import vit
from compile.configs import TEST, ModelConfig, QuantConfig
from compile.params import (
    flatten_tree,
    init_params,
    load_npz,
    reinit_qsteps,
    save_npz,
    tree_count,
    unflatten_into,
)

CFG = TEST
QCFG = QuantConfig(bits=3)


def test_patchify_shape_and_content():
    cfg = ModelConfig(img_size=8, patch_size=4, in_chans=3, dim=16, depth=1, heads=2)
    imgs = jnp.arange(8 * 8 * 3, dtype=jnp.float32).reshape(1, 8, 8, 3)
    p = vit.patchify(imgs, cfg)
    assert p.shape == (1, 4, 48)
    # first patch = rows 0..3 × cols 0..3
    img = np.asarray(imgs[0])
    want = img[:4, :4, :].reshape(-1)
    np.testing.assert_array_equal(np.asarray(p[0, 0]), want)


def test_forward_shapes_all_modes():
    params = init_params(jax.random.PRNGKey(0), CFG, QCFG)
    x = jnp.zeros((2, CFG.img_size, CFG.img_size, 3))
    assert vit.forward_fp32(params, x, CFG).shape == (2, CFG.num_classes)
    assert vit.forward_qvit(params, x, CFG, QCFG).shape == (2, CFG.num_classes)


def test_forward_deterministic():
    params = init_params(jax.random.PRNGKey(0), CFG, QCFG)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, CFG.img_size, CFG.img_size, 3)).astype(np.float32))
    a = np.asarray(vit.forward_fp32(params, x, CFG))
    b = np.asarray(vit.forward_fp32(params, x, CFG))
    np.testing.assert_array_equal(a, b)


def test_param_count_scales_with_depth():
    small = init_params(jax.random.PRNGKey(0), CFG, QCFG)
    big_cfg = ModelConfig(
        img_size=CFG.img_size, patch_size=CFG.patch_size, dim=CFG.dim, depth=CFG.depth * 2, heads=CFG.heads
    )
    big = init_params(jax.random.PRNGKey(0), big_cfg, QCFG)
    assert tree_count(big) > 1.7 * tree_count(small)


def test_accuracy_metric():
    logits = jnp.asarray([[1.0, 2.0], [3.0, 0.0]])
    labels = jnp.asarray([1, 0])
    assert float(vit.accuracy(logits, labels)) == 1.0
    assert float(vit.accuracy(logits, jnp.asarray([0, 0]))) == 0.5


def test_checkpoint_roundtrip(tmp_path):
    params = init_params(jax.random.PRNGKey(1), CFG, QCFG)
    p = tmp_path / "ck.npz"
    save_npz(p, params)
    template = init_params(jax.random.PRNGKey(2), CFG, QCFG)
    restored = load_npz(p, template)
    for k, v in flatten_tree(params).items():
        np.testing.assert_array_equal(v, flatten_tree(restored)[k], err_msg=k)


def test_unflatten_missing_leaf_raises(tmp_path):
    params = init_params(jax.random.PRNGKey(1), CFG, QCFG)
    flat = flatten_tree(params)
    key = next(iter(flat))
    del flat[key]
    try:
        unflatten_into(params, flat)
        raise AssertionError("should have raised")
    except KeyError as e:
        assert key.split(".")[0] in str(e) or key in str(e)


def test_reinit_qsteps_changes_only_q():
    params = init_params(jax.random.PRNGKey(0), CFG, QCFG)
    re = reinit_qsteps(params, CFG, QuantConfig(bits=2))
    # weights untouched
    np.testing.assert_array_equal(
        np.asarray(params["blocks"][0]["attn"]["wq"]["w"]),
        np.asarray(re["blocks"][0]["attn"]["wq"]["w"]),
    )
    # q-steps re-derived (2-bit qmax differs)
    a = float(params["blocks"][0]["q"]["attn"]["sw_q"][0])
    b = float(re["blocks"][0]["q"]["attn"]["sw_q"][0])
    assert a != b
