"""The paper's central claims at module level:

1. operand reordering is LOSSLESS: integerized attention with exact exp
   equals the Q-ViT fake-quant attention (up to fp associativity / rare
   quantizer tie flips);
2. the Pallas-kernel composition equals the jnp integerized path exactly;
3. the shift-softmax is the only approximation, and its effect is small.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import attention, integerize, vit
from compile.configs import TEST, QuantConfig
from compile.kernels import ref
from compile.params import init_params
from compile.quantizers import quantize_int

CFG = TEST
QCFG = QuantConfig(bits=3, attn_bits=3)


@pytest.fixture(scope="module")
def setup():
    params = init_params(jax.random.PRNGKey(0), CFG, QCFG)
    ip = integerize.integerize(params, CFG, QCFG)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(4, CFG.img_size, CFG.img_size, 3)).astype(np.float32)
    return params, ip, jnp.asarray(x)


def test_reordering_is_lossless_full_model(setup):
    params, ip, x = setup
    lq = np.asarray(vit.forward_qvit(params, x, CFG, QCFG))
    li = np.asarray(vit.forward_int(ip, x, CFG, QCFG, shift=False))
    # fp-associativity + quantizer tie flips bound the drift; argmax must agree
    assert np.abs(lq - li).max() < 0.1
    np.testing.assert_array_equal(lq.argmax(-1), li.argmax(-1))


def test_shift_softmax_is_the_only_approximation(setup):
    params, ip, x = setup
    exact = np.asarray(vit.forward_int(ip, x, CFG, QCFG, shift=False))
    shift = np.asarray(vit.forward_int(ip, x, CFG, QCFG, shift=True))
    # different but close
    assert not np.array_equal(exact, shift)
    assert np.abs(exact - shift).max() < 1.5


def test_pallas_composition_equals_jnp_int_path(setup):
    _, ip, x = setup
    blk = ip["blocks"][0]["attn"]
    h = ref.layernorm(
        vit._embed(ip, x, CFG), ip["blocks"][0]["ln1"]["g"], ip["blocks"][0]["ln1"]["b"]
    )
    codes = quantize_int(h, blk["sx"], QCFG.bits)
    want = attention.attention_int(blk, codes, CFG, QCFG, shift=True)
    got = attention.attention_int_pallas(blk, codes[0], CFG, QCFG, shift=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want[0]), rtol=1e-5, atol=1e-5)


def test_integerized_block_consumes_integer_codes_only(setup):
    # the integer path must be invariant to *how* codes were produced:
    # feeding the same integer codes gives identical output (no hidden fp
    # dependence on the unquantized input).
    _, ip, _ = setup
    blk = ip["blocks"][0]["attn"]
    rng = np.random.default_rng(3)
    codes = rng.integers(QCFG.qmin, QCFG.qmax + 1, (1, CFG.tokens, CFG.dim)).astype(np.int32)
    a = attention.attention_int(blk, jnp.asarray(codes), CFG, QCFG)
    b = attention.attention_int(blk, jnp.asarray(codes).astype(jnp.float32), CFG, QCFG)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


def test_fp32_attention_softmax_normalised(setup):
    params, _, x = setup
    h = jnp.asarray(np.random.default_rng(1).normal(size=(2, CFG.tokens, CFG.dim)).astype(np.float32))
    out = attention.attention_fp32(params["blocks"][0]["attn"], h, CFG)
    assert out.shape == (2, CFG.tokens, CFG.dim)
    assert np.all(np.isfinite(np.asarray(out)))


def test_scale_cancellation_in_layernorm():
    # LN(c·v) == LN(v) for scalar c>0 — the identity that lets Eq. 2 drop Δ̄_X.
    rng = np.random.default_rng(2)
    v = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
    g = jnp.asarray((0.5 + rng.random(32)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=32).astype(np.float32))
    a = ref.layernorm(v, g, b)
    c = ref.layernorm(17.3 * v, g, b)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-4, atol=1e-4)
