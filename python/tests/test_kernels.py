"""Pallas kernels vs the pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes, bit-widths and scales; assertions are exact on
integer outputs and allclose on float outputs. interpret=True keeps this
executable on CPU (and is the same lowering the AOT artifacts embed).
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    attn_value_pallas,
    int_linear_pallas,
    qk_shift_softmax_pallas,
    qlayernorm_pallas,
)
from compile.kernels import ref

SETTINGS = dict(max_examples=15, deadline=None)


def codes(rng, shape, bits):
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    return rng.integers(lo, hi + 1, shape).astype(np.int32)


@settings(**SETTINGS)
@given(
    m=st.sampled_from([32, 64]),
    k=st.sampled_from([16, 48, 128]),
    n=st.sampled_from([32, 96]),
    bits=st.sampled_from([2, 3, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_int_linear_matches_ref(m, k, n, bits, seed):
    rng = np.random.default_rng(seed)
    xq = codes(rng, (m, k), bits)
    wq = codes(rng, (n, k), bits)
    b = rng.normal(size=n).astype(np.float32)
    sw = (0.01 + rng.random(n) * 0.2).astype(np.float32)
    sx = float(0.01 + rng.random() * 0.2)
    got = int_linear_pallas(jnp.asarray(xq), jnp.asarray(wq), jnp.asarray(b), sx, jnp.asarray(sw))
    want = ref.int_linear(jnp.asarray(xq), jnp.asarray(wq), jnp.asarray(b), sx, jnp.asarray(sw))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_int_linear_equals_dequant_path():
    rng = np.random.default_rng(0)
    xq, wq = codes(rng, (64, 32), 3), codes(rng, (32, 32), 3)
    b = rng.normal(size=32).astype(np.float32)
    sw = (0.02 + rng.random(32) * 0.1).astype(np.float32)
    got = int_linear_pallas(jnp.asarray(xq), jnp.asarray(wq), jnp.asarray(b), 0.07, jnp.asarray(sw))
    want = ref.dequant_linear(jnp.asarray(xq), jnp.asarray(wq), jnp.asarray(b), 0.07, jnp.asarray(sw))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(**SETTINGS)
@given(
    m=st.sampled_from([32, 64]),
    n=st.sampled_from([32, 64]),
    d=st.sampled_from([16, 32]),
    attn_bits=st.sampled_from([2, 3, 4]),
    shift=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_qk_shift_softmax_matches_ref(m, n, d, attn_bits, shift, seed):
    rng = np.random.default_rng(seed)
    qq, kq = codes(rng, (m, d), 3), codes(rng, (n, d), 3)
    scale = float(0.005 + rng.random() * 0.05) / np.sqrt(d)
    step = 1.0 / (2**attn_bits - 1)
    got = qk_shift_softmax_pallas(jnp.asarray(qq), jnp.asarray(kq), scale, step, attn_bits, shift=shift)
    want, _ = ref.qk_shift_softmax(jnp.asarray(qq), jnp.asarray(kq), scale, step, attn_bits, shift=shift)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(**SETTINGS)
@given(
    m=st.sampled_from([32, 64]),
    n=st.sampled_from([32, 64]),
    d=st.sampled_from([32, 64]),
    out_bits=st.sampled_from([2, 3, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attn_value_matches_ref(m, n, d, out_bits, seed):
    rng = np.random.default_rng(seed)
    aq = rng.integers(0, 8, (m, n)).astype(np.int32)
    vq = codes(rng, (n, d), 3)
    sa, sv, so = 1.0 / 7, float(0.02 + rng.random() * 0.1), float(0.05 + rng.random() * 0.1)
    got = attn_value_pallas(jnp.asarray(aq), jnp.asarray(vq), sa, sv, so, out_bits)
    want, _ = ref.attn_value(jnp.asarray(aq), jnp.asarray(vq), sa, sv, so, out_bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(**SETTINGS)
@given(
    m=st.sampled_from([32, 64]),
    d=st.sampled_from([32, 128]),
    bits=st.sampled_from([2, 3, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_qlayernorm_matches_round_form(m, d, bits, seed):
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(m, d)) * 2).astype(np.float32)
    g = (0.3 + rng.random(d)).astype(np.float32)
    b = (rng.normal(size=d) * 0.3).astype(np.float32)
    step = float(0.2 + rng.random() * 0.5)
    got = qlayernorm_pallas(jnp.asarray(x), jnp.asarray(g), jnp.asarray(b), step, bits)
    want = ref.qlayernorm(jnp.asarray(x), jnp.asarray(g), jnp.asarray(b), step, bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_qlayernorm_negative_gamma():
    rng = np.random.default_rng(5)
    x = (rng.normal(size=(32, 16)) * 2).astype(np.float32)
    g = -np.abs(0.5 + rng.random(16)).astype(np.float32)  # all negative
    b = np.zeros(16, np.float32)
    got = qlayernorm_pallas(jnp.asarray(x), jnp.asarray(g), jnp.asarray(b), 0.4, 3)
    want = ref.qlayernorm(jnp.asarray(x), jnp.asarray(g), jnp.asarray(b), 0.4, 3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_shift_exp_properties():
    x = jnp.linspace(-12.0, 3.0, 301)
    approx = np.asarray(ref.shift_exp(x))
    exact = np.exp(np.asarray(x))
    rel = np.abs(approx - exact) / exact
    assert rel.max() < 0.062  # Mitchell bound
    assert np.all(approx + 1e-9 >= exact)  # 1+r ≥ 2^r: always overestimates
    assert np.all(np.diff(approx) > 0)  # monotone


def test_comparator_form_equals_round_form():
    rng = np.random.default_rng(6)
    x = (rng.normal(size=(64, 48)) * 3).astype(np.float32)
    g = (rng.uniform(-1.5, 1.5, 48)).astype(np.float32)
    b = (rng.normal(size=48) * 0.2).astype(np.float32)
    a = ref.qlayernorm(jnp.asarray(x), jnp.asarray(g), jnp.asarray(b), 0.37, 3)
    c = ref.qlayernorm_comparator(jnp.asarray(x), jnp.asarray(g), jnp.asarray(b), 0.37, 3)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_welford_matches_two_pass():
    rng = np.random.default_rng(7)
    x = (rng.normal(size=(8, 200)) * 5).astype(np.float32)
    mu, var = ref.welford(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(mu), x.mean(-1), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(var), x.var(-1), rtol=1e-4, atol=1e-4)
