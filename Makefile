# Convenience targets for the ivit reproduction.
#
#   make tier1       — the repo's tier-1 gate: release build + full test suite
#   make fmt         — rustfmt check (no changes applied)
#   make clippy      — lint gate: cargo clippy with warnings denied
#   make bench       — the artifact-free benches (table1, sim speed, ablations)
#   make bench-smoke — CI smoke: one tiny batch through every backend plan
#                      (asserts bit-identical outputs across dispatch modes)
#   make eval-smoke  — CI smoke: artifact-free `ivit eval --backend ref` on a
#                      tiny synthetic checkpoint (8 images through the
#                      integerized encoder-block stack, no PJRT needed)
#   make serve-smoke — CI smoke: artifact-free block-scope `ivit serve` (a
#                      fixed request count through the pipelined coordinator
#                      and a whole encoder block on the ref backend)
#   make profile-smoke — CI smoke for per-module mixed precision: one batch
#                      through an attn:4,mlp:8 encoder block with ref ≡ sim
#                      bit-identity asserted (examples/profile_smoke.rs) plus
#                      a tiny mixed-profile `ivit eval --backend ref`
#   make jit-smoke   — CI smoke for the kernel codegen subsystem: one batch
#                      through a compiled (jit) encoder block with jit ≡ ref
#                      bit-identity asserted (examples/jit_smoke.rs), run
#                      twice — once pinned to the scalar GEMM microkernel
#                      (IVIT_KERNEL_ISA=scalar) and once auto-detected — so
#                      every available ISA proves bit-identity in CI
#   make po2-smoke   — CI smoke for power-of-two scale chains: a tiny
#                      `:po2` encoder block with the compiled shift-only
#                      requant datapath asserted bit-identical to the fp
#                      interpreter, and the systolic sim's shifter/fp
#                      requant energy split asserted positive with
#                      ref-pinned numerics (examples/po2_smoke.rs)
#   make trace-smoke — CI smoke for the observability subsystem: tiny jit and
#                      ref block-scope serves with --trace, then
#                      examples/trace_smoke.rs asserts both Chrome traces are
#                      schema-valid (admit→respond pipeline kinds; one span per
#                      kernel stage kind in the jit trace) and that tracing
#                      on ≡ off is bit-identical
#   make serve-net-smoke — CI smoke for the wire protocol: a loopback-UDS
#                      `ivit serve --listen` server plus an `ivit request`
#                      client, with every reply asserted bit-identical to a
#                      local reference run of the same block (--verify-local)
#   make artifacts   — lower the JAX model to HLO + export eval set / attn_case
#                      (needs the python toolchain; see python/compile/)

RUST_DIR := rust

.PHONY: tier1 fmt clippy bench bench-smoke eval-smoke serve-smoke profile-smoke jit-smoke po2-smoke trace-smoke serve-net-smoke artifacts

tier1:
	cd $(RUST_DIR) && cargo build --release && cargo test -q

fmt:
	cd $(RUST_DIR) && cargo fmt --check

clippy:
	cd $(RUST_DIR) && cargo clippy --all-targets -- -D warnings

bench:
	cd $(RUST_DIR) && cargo bench --bench table1_power --bench sim_speed --bench ablation_scales --bench fig_softmax_error

bench-smoke:
	cd $(RUST_DIR) && IVIT_BENCH_SMOKE=1 cargo bench --bench throughput

eval-smoke:
	cd $(RUST_DIR) && cargo run --release -q -- eval --backend ref --limit 8 --images 8

serve-smoke:
	cd $(RUST_DIR) && cargo run --release -q -- serve --backend ref --scope block \
		--tokens 16 --dim 32 --hidden 64 --heads 2 --batch 2 --requests 8

profile-smoke:
	cd $(RUST_DIR) && cargo run --release -q --example profile_smoke
	cd $(RUST_DIR) && cargo run --release -q -- eval --backend ref \
		--bits-profile "attn:4,mlp:8" --dim 16 --hidden 32 --patch 8 \
		--limit 4 --images 4

jit-smoke:
	cd $(RUST_DIR) && IVIT_KERNEL_ISA=scalar cargo run --release -q --example jit_smoke
	cd $(RUST_DIR) && cargo run --release -q --example jit_smoke

po2-smoke:
	cd $(RUST_DIR) && cargo run --release -q --example po2_smoke

trace-smoke:
	cd $(RUST_DIR) && cargo run --release -q -- serve --backend jit --scope block \
		--tokens 16 --dim 32 --hidden 64 --heads 2 --batch 2 --requests 8 \
		--trace /tmp/ivit_trace_jit.json
	cd $(RUST_DIR) && cargo run --release -q -- serve --backend ref --scope block \
		--tokens 16 --dim 32 --hidden 64 --heads 2 --batch 2 --requests 8 \
		--trace /tmp/ivit_trace_ref.json
	cd $(RUST_DIR) && cargo run --release -q --example trace_smoke -- \
		/tmp/ivit_trace_jit.json /tmp/ivit_trace_ref.json

serve-net-smoke:
	cd $(RUST_DIR) && cargo build --release -q
	@set -e; \
	sock=/tmp/ivit_net_smoke_$$$$.sock; \
	rm -f $$sock; \
	$(RUST_DIR)/target/release/ivit serve --backend ref --scope block \
	  --listen uds:$$sock --serve-timeout-s 120 \
	  --tokens 16 --dim 32 --hidden 64 --heads 2 --batch 2 --requests 8 & \
	server=$$!; \
	for i in $$(seq 1 200); do [ -S $$sock ] && break; sleep 0.05; done; \
	[ -S $$sock ] || { echo "serve-net-smoke: server socket never appeared" >&2; kill $$server 2>/dev/null; exit 1; }; \
	$(RUST_DIR)/target/release/ivit request --connect uds:$$sock --tenant smoke \
	  --count 8 --tokens 16 --dim 32 --hidden 64 --heads 2 --verify-local \
	  || { kill $$server 2>/dev/null; exit 1; }; \
	wait $$server

artifacts:
	cd python && python3 -m compile.aot --out ../$(RUST_DIR)/artifacts
